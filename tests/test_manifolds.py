"""Property tests for the Stiefel primitives (paper Preliminaries)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import manifolds as M

SET = dict(deadline=None, max_examples=15)


def _rand_point(seed, d, r):
    return M.random_stiefel(jax.random.PRNGKey(seed), d, r)


@st.composite
def dims(draw):
    d = draw(st.integers(3, 48))
    r = draw(st.integers(1, min(d, 12)))
    seed = draw(st.integers(0, 2 ** 16))
    return d, r, seed


@given(dims())
@settings(**SET)
def test_tangent_projection_properties(dr):
    d, r, seed = dr
    x = _rand_point(seed, d, r)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, r))
    u = M.tangent_project(x, g)
    # u is tangent: x^T u + u^T x = 0
    assert M.is_tangent(x, u, atol=1e-4)
    # idempotent
    np.testing.assert_allclose(M.tangent_project(x, u), u, atol=1e-5)
    # P(x) = 0  (the identity the consensus step relies on)
    np.testing.assert_allclose(M.tangent_project(x, x), 0.0, atol=1e-5)


@given(dims())
@settings(**SET)
def test_polar_retraction_feasibility_and_rigidity(dr):
    d, r, seed = dr
    x = _rand_point(seed, d, r)
    g = jax.random.normal(jax.random.PRNGKey(seed + 2), (d, r))
    u = M.tangent_project(x, g)
    y = M.retract_polar(x, 0.1 * u)
    assert float(M.stiefel_error(y)) < 1e-4
    # R_x(0) = x
    np.testing.assert_allclose(M.retract_polar(x, jnp.zeros_like(x)), x,
                               atol=1e-5)


@given(dims())
@settings(**SET)
def test_polar_nonexpansiveness_lemma1(dr):
    """Lemma 1 (Eq. 7): ||R_x(u) - z|| <= ||x + u - z|| for z on St."""
    d, r, seed = dr
    x = _rand_point(seed, d, r)
    z = _rand_point(seed + 7, d, r)
    u = M.tangent_project(x, jax.random.normal(jax.random.PRNGKey(seed + 3),
                                               (d, r)))
    u = 0.5 * u
    lhs = float(jnp.linalg.norm(M.retract_polar(x, u, method="eigh") - z))
    rhs = float(jnp.linalg.norm(x + u - z))
    assert lhs <= rhs + 1e-4


@given(dims())
@settings(**SET)
def test_second_order_boundedness_eq6(dr):
    """Eq. (6): ||R_x(u) - (x+u)|| <= M ||u||^2 — check with a generous M."""
    d, r, seed = dr
    x = _rand_point(seed, d, r)
    u = M.tangent_project(x, jax.random.normal(jax.random.PRNGKey(seed + 4),
                                               (d, r)))
    for scale in (0.3, 0.1, 0.03):
        us = scale * u / max(float(jnp.linalg.norm(u)), 1e-9)
        resid = float(jnp.linalg.norm(M.retract_polar(x, us) - (x + us)))
        assert resid <= 2.0 * float(jnp.sum(us * us)) + 1e-5


def test_newton_schulz_matches_eigh():
    for seed, (d, r) in enumerate([(16, 4), (64, 16), (128, 128), (200, 9)]):
        x = _rand_point(seed, d, r)
        u = 0.2 * M.tangent_project(
            x, jax.random.normal(jax.random.PRNGKey(seed + 5), (d, r)))
        y_ns = M.retract_polar(x, u, method="ns")
        y_ei = M.retract_polar(x, u, method="eigh")
        np.testing.assert_allclose(y_ns, y_ei, atol=5e-5)


def test_project_stiefel_is_nearest_point():
    x = _rand_point(0, 20, 5)
    a = x + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (20, 5))
    p = M.project_stiefel(a, method="eigh")
    assert float(M.stiefel_error(p)) < 1e-4
    # projection is at least as close as x itself
    assert float(jnp.linalg.norm(a - p)) <= float(jnp.linalg.norm(a - x)) + 1e-6


def test_iam_consensus(rng):
    base = _rand_point(3, 24, 6)
    pert = jnp.asarray(rng.normal(size=(8, 24, 6)) * 0.01, jnp.float32)
    xs = jax.vmap(lambda e: M.retract_polar(base, M.tangent_project(base, e)))(pert)
    xhat = M.induced_arithmetic_mean(xs, method="eigh")
    assert float(M.stiefel_error(xhat)) < 1e-4
    # IAM of identical points is the point
    same = jnp.broadcast_to(base[None], (5, 24, 6))
    np.testing.assert_allclose(M.induced_arithmetic_mean(same, "eigh"), base,
                               atol=1e-5)
    assert float(M.consensus_error(same)) < 1e-9


def test_rgd_step_descends():
    a = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    a = (a + a.T) / 2

    def f(x):
        return -jnp.trace(x.T @ a @ x)     # PCA: minimize negative Rayleigh

    x = _rand_point(9, 16, 3)
    vals = [float(f(x))]
    for _ in range(50):
        x = M.rgd_step(x, jax.grad(f)(x), 0.05)
        vals.append(float(f(x)))
    assert vals[-1] < vals[0]
    assert float(M.stiefel_error(x)) < 1e-4
