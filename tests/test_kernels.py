"""Pallas kernel sweeps: interpret-mode kernel vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import manifolds as M
from repro.kernels import ops, ref

SET = dict(deadline=None, max_examples=10)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # b, s, t, h, hkv, hd, hdv, causal, window, dtype
    (1, 128, 128, 4, 4, 32, 32, True, None, jnp.float32),
    (2, 64, 64, 8, 2, 64, 64, True, None, jnp.float32),
    (1, 128, 128, 4, 1, 32, 32, True, 48, jnp.float32),     # window + MQA
    (2, 1, 256, 8, 2, 64, 64, True, None, jnp.float32),     # decode
    (1, 96, 160, 4, 4, 16, 16, True, None, jnp.float32),    # ragged/padding
    (1, 64, 64, 4, 2, 32, 16, True, None, jnp.float32),     # hd_v != hd_k
    (1, 64, 64, 4, 4, 32, 32, False, None, jnp.float32),    # non-causal (cross)
    (1, 64, 64, 4, 4, 32, 32, True, None, jnp.bfloat16),    # bf16
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_oracle(case):
    b, s, t, h, hkv, hd, hdv, causal, window, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, t, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, t, hkv, hdv), dtype)
    qpos = jnp.broadcast_to(jnp.arange(t - s, t), (b, s)) if s < t else None
    want = ref.attention_naive(q, k, v, causal=causal, window=window,
                               q_positions=qpos)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_positions=qpos, impl="pallas_interpret",
                              block_q=32, block_kv=64)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)
    blk = ref.blockwise_attention(q, k, v, causal=causal, window=window,
                                  q_positions=qpos, chunk=48)
    np.testing.assert_allclose(np.asarray(blk, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_attention_ring_cache_positions():
    """Ring-buffer cache: unordered kv positions must still mask correctly."""
    b, t, h, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    k = jax.random.normal(ks[1], (b, t, h, hd))
    v = jax.random.normal(ks[2], (b, t, h, hd))
    # cache holding positions 64..127 at slots (p % 64), query at pos 127
    kvpos = jnp.arange(64, 128)[None, :]
    kvpos = jnp.roll(kvpos, 7, axis=1)
    qpos = jnp.full((b, 1), 127)
    want = ref.attention_naive(q, k, v, causal=True, q_positions=qpos,
                               kv_positions=kvpos)
    got = ops.flash_attention(q, k, v, causal=True, q_positions=qpos,
                              kv_positions=kvpos, impl="pallas_interpret",
                              block_q=8, block_kv=32)
    np.testing.assert_allclose(got, want, atol=2e-5)


# ---------------------------------------------------------------------------
# stiefel projection
# ---------------------------------------------------------------------------


@st.composite
def proj_dims(draw):
    d = draw(st.integers(2, 300))
    r = draw(st.integers(1, min(d, 96)))
    seed = draw(st.integers(0, 2 ** 16))
    return d, r, seed


@given(proj_dims())
@settings(**SET)
def test_stiefel_project_kernel_sweep(drs):
    d, r, seed = drs
    x = M.random_stiefel(jax.random.PRNGKey(seed), d, r)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, r))
    want = ref.stiefel_project_ref(x, g)
    got = ops.stiefel_project(x, g, impl="pallas_interpret")
    np.testing.assert_allclose(got, want, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch", [(), (3,), (2, 2)])
def test_stiefel_project_batched_dtypes(batch, dtype):
    x = M.random_stiefel(jax.random.PRNGKey(0), 64, 16, batch=batch).astype(dtype)
    g = jax.random.normal(jax.random.PRNGKey(1), (*batch, 64, 16), dtype)
    want = ref.stiefel_project_ref(x, g)
    got = ops.stiefel_project(x, g, impl="pallas_interpret")
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# ring mix
# ---------------------------------------------------------------------------


@given(st.integers(1, 4000), st.integers(0, 1000))
@settings(**SET)
def test_ring_mix_kernel_sweep(n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b, c = (jax.random.normal(k, (n,)) for k in ks)
    want = ref.ring_mix_ref(a, b, c, 0.4, 0.3)
    got = ops.ring_mix(a, b, c, w_self=0.4, w_side=0.3,
                       impl="pallas_interpret")
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("shape", [
    (1,),                # single element
    (9973,)              # prime: 10 panel rows, ragged both ways
    , (13, 1024),        # 13 rows — no old block-candidate divides it
    (5, 1024 + 1),       # lane tail + odd row count
    (3, 7, 191),         # multi-dim ragged leaf
    (30 * 1024 + 7,),    # row tail past the 8-sublane boundary
])
def test_ring_mix_ragged_shapes(shape):
    """Arbitrary leaf sizes tile cleanly: the dispatch pads ragged lane AND
    row tails (and slices back) instead of degenerating to 1-row blocks or
    tripping the kernel's tiling contract."""
    ks = jax.random.split(jax.random.PRNGKey(sum(shape)), 3)
    a, b, c = (jax.random.normal(k, shape) for k in ks)
    want = ref.ring_mix_ref(a, b, c, 1 / 3, 1 / 3)
    got = ops.ring_mix(a, b, c, w_self=1 / 3, w_side=1 / 3,
                       impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
