"""Dry-run machinery tests.

The full 512-device production dry-run runs via ``python -m
repro.launch.dryrun`` (results in experiments/dryrun/ + EXPERIMENTS.md).
Here we validate the same code path end-to-end in a subprocess with a tiny
8-device placeholder grid (fast on CPU), plus the HLO collective parser and
sharding rules in-process.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch import roofline

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collective_parser():
    hlo = """
  %ag = bf16[2,128,512]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%sum
  %cp = bf16[64,64]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = (f32[32,8]{1,0}, f32[32,8]{1,0}) all-to-all(%p, %q), dimensions={0}
  %rs = f32[256]{0} reduce-scatter(%w), to_apply=%sum
  %other = f32[9]{0} add(%a, %b)
"""
    got = roofline.collective_bytes(hlo)
    assert got["all-gather"] == 2 * 128 * 512 * 2
    assert got["all-reduce"] == 1024 * 4
    assert got["collective-permute"] == 64 * 64 * 2
    assert got["all-to-all"] == 2 * 32 * 8 * 4
    assert got["reduce-scatter"] == 256 * 4


def test_roofline_terms_math():
    t = roofline.RooflineTerms(
        flops_per_dev=197e12, bytes_per_dev=819e9,
        collective_bytes_per_dev=50e9, collective_breakdown={}, chips=256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.0) < 1e-9
    t2 = roofline.RooflineTerms(1e12, 900e9, 1e9, {}, 256)
    assert t2.dominant == "memory"


@pytest.mark.parametrize("case", [
    ("granite-3-2b", "train"), ("granite-moe-1b-a400m", "train"),
    ("zamba2-2.7b", "decode"), ("musicgen-large", "decode"),
])
def test_dryrun_smoke_subprocess(case, tmp_path):
    """Lower+compile a SMOKE config through the exact dryrun path on an
    8-device placeholder grid in a subprocess."""
    arch, mode = case
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro import configs
from repro.configs.base import InputShape
from repro.launch.steps import abstract_train_state, build_trainer, make_serve_step
from repro.launch import roofline
from repro.sharding import partition
from repro.models import transformer as T

cfg = configs.get_config({arch!r}, smoke=True)
mode = {mode!r}
if mode == "train":
    mesh = Mesh(np.asarray(jax.devices())[:8].reshape(2, 2, 2),
                ("node", "fsdp", "model"))
    shape = InputShape("t", 64, 8, "train")
    opt, _ = build_trainer(cfg, 2, dtype=jnp.bfloat16)
    bs = configs.input_specs(cfg, shape, 2, activation_dtype=jnp.bfloat16)
    ss = abstract_train_state(cfg, opt, 2, bs, dtype=jnp.bfloat16)
    ssh = partition.train_state_shardings(ss, mesh, False)
    bsh = partition.train_batch_shardings(bs, mesh, False)
    with mesh:
        low = jax.jit(opt.step, in_shardings=(ssh, bsh),
                      out_shardings=(ssh, None), donate_argnums=(0,)
                      ).lower(ss, bs)
else:
    mesh = Mesh(np.asarray(jax.devices())[:8].reshape(2, 4),
                ("data", "model"))
    shape = InputShape("d", 64, 8, "decode")
    ps = jax.eval_shape(lambda k: T.init_params(k, cfg, jnp.bfloat16),
                        jax.random.PRNGKey(0))
    psh = partition.serve_param_shardings(ps, mesh)
    ins = configs.input_specs(cfg, shape, activation_dtype=jnp.bfloat16)
    insh = partition.serve_batch_shardings(ins, mesh, False)
    kw = {{}}
    if cfg.frontend is not None:
        kw["frontend_embeds"] = ins["frontend_embeds"]
    with mesh:
        low = jax.jit(make_serve_step(cfg),
                      in_shardings=(psh, insh["token"], insh["position"],
                                    insh["cache"])).lower(
            ps, ins["token"], ins["position"], ins["cache"], **kw)
comp = low.compile()
terms = roofline.derive(comp, 8)
assert terms.flops_per_dev > 0
print(json.dumps({{"ok": True, "dominant": terms.dominant,
                   "collectives": terms.collective_breakdown}}))
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["ok"]
    # the decentralized gossip must appear as collectives on the wire
    if mode == "train":
        assert sum(rec["collectives"].values()) > 0


def test_production_dryrun_artifacts_exist_and_lower():
    """The full-size dry-run table is produced by the background sweep; if
    present, sanity-check the records."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("production dry-run artifacts not generated yet")
    recs = []
    for f in os.listdir(d):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                recs.append(json.load(fh))
    assert recs
    for r in recs:
        assert r["chips"] in (256, 512)
        assert r["roofline"]["flops_per_dev"] > 0
        assert r["roofline"]["dominant"] in ("compute", "memory", "collective")


def test_mesh_plans_cover_256():
    from repro import configs as C
    for arch in C.ARCH_IDS:
        plan = C.get_config(arch).mesh_plan
        assert plan.node * plan.fsdp * plan.model == 256
