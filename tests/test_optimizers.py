"""Optimizer behaviour on a controlled toy minimax problem.

Toy task (robust PCA-flavoured, matches the paper's problem class):
  min_{x in St(d,r)} max_{y in simplex_G}
      sum_g y_g * ( -tr(x^T A_g x) ) - rho ||y - 1/G||^2
with per-node perturbations of A_g (data heterogeneity).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import OPTIMIZERS, manifolds as M
from repro.core.baselines import GTSRVR, SRVRHyper
from repro.core.gda import DRGDA, DRSGDA, GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.metric import convergence_metric
from repro.core.minimax import MinimaxProblem, project_simplex

D, R, G, N_NODES = 10, 2, 3, 6
RHO = 1.0


def _make_problem(seed=0):
    # per-group symmetric matrices (validated instance: isolated saddle)
    a = np.stack([np.random.RandomState(seed + i).randn(D, D)
                  for i in range(G)])
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)

    def loss_fn(x, y, batch):
        ag = a + batch                      # (G, D, D) node perturbation
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return jnp.dot(y, lg) - RHO * jnp.sum((y - 1.0 / G) ** 2)

    def y_star(x, batches):
        ag = a + jnp.mean(batches, axis=0)
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return project_simplex(1.0 / G + lg / (2 * RHO))

    return MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          manifold_map={"w": "stiefel"}, y_star=y_star)


def _batches(seed=6, scale=0.1):
    return scale * jax.random.normal(jax.random.PRNGKey(seed),
                                     (N_NODES, G, D, D))


def _init(seed=5):
    x0 = broadcast_to_nodes(
        {"w": M.random_stiefel(jax.random.PRNGKey(seed), D, R)}, N_NODES)
    y0 = jnp.full((N_NODES, G), 1.0 / G)
    return x0, y0


def test_drgda_converges_and_stays_feasible():
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    opt = DRGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.03, eta=0.3))
    x0, y0 = _init()
    batches = _batches()
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    m0 = convergence_metric(prob, state.x, state.y, batches)
    for _ in range(600):
        state, metrics = step(state, batches)
    m = convergence_metric(prob, state.x, state.y, batches)
    assert float(m["M_t"]) < 1e-3 and float(m["M_t"]) < 0.01 * float(m0["M_t"])
    assert float(m["stiefel_residual"]) < 1e-4
    assert float(m["consensus_x"]) < 1e-4
    assert float(metrics.consensus_y) < 1e-6


def test_drgda_tracker_tracks_mean_gradient():
    """Gradient-tracking invariant: mean_i u_i == mean_i grad f_i."""
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, k_steps=2)
    opt = DRGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.05, eta=0.2))
    x0, y0 = _init()
    batches = _batches()
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    for _ in range(7):
        state, _ = step(state, batches)
    rgx, _ = jax.vmap(prob.rgrads)(state.x, state.y, batches)
    np.testing.assert_allclose(np.mean(np.asarray(state.u["w"]), 0),
                               np.mean(np.asarray(rgx["w"]), 0), atol=1e-5)


def test_drsgda_converges_with_noise():
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    opt = DRSGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.03, eta=0.15))
    x0, y0 = _init()
    state = opt.init(x0, y0, _batches(seed=100))
    step = opt.make_step(donate=False)
    eval_batches = _batches(seed=0, scale=0.0)   # noiseless eval
    m0 = convergence_metric(prob, state.x, state.y, eval_batches)
    for t in range(300):
        state, _ = step(state, _batches(seed=101 + t))   # fresh minibatch
    m = convergence_metric(prob, state.x, state.y, eval_batches)
    assert float(m["M_t"]) < 0.2 * float(m0["M_t"])
    assert float(m["stiefel_residual"]) < 1e-4


@pytest.mark.parametrize("name", ["gt-gda", "gnsd-a", "dm-hsgd"])
def test_baselines_run_and_stay_feasible(name):
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    cls = OPTIMIZERS[name]
    opt = cls(prob, spec) if name == "dm-hsgd" else \
        cls(prob, spec, GDAHyper(beta=0.03, eta=0.15))
    x0, y0 = _init()
    batches = _batches()
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    losses = []
    for t in range(120):
        state, metrics = step(state, batches)
        losses.append(float(metrics.loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    assert float(M.stiefel_error(state.x["w"]).max()) < 1e-3


def test_gtsrvr_anchor_alternation():
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    opt = GTSRVR(prob, spec, SRVRHyper(beta=0.03, eta=0.15, q=8))
    x0, y0 = _init()
    anchor = _batches(seed=50, scale=0.0)
    state = opt.init(x0, y0, anchor)
    step, anchor_step = opt.make_step(donate=False)
    losses = []
    for t in range(80):
        if t % opt.hyper.q == 0:
            state, metrics = anchor_step(state, anchor)
        else:
            state, metrics = step(state, _batches(seed=200 + t))
        losses.append(float(metrics.loss))
    assert np.isfinite(losses).all() and losses[-1] < losses[0]
    assert float(M.stiefel_error(state.x["w"]).max()) < 1e-3


def test_drgda_beats_gtgda_on_feasibility_path():
    """The paper's qualitative claim: the retraction-based update stays on
    the manifold along the whole trajectory, whereas the Euclidean baseline
    drifts between its update and projection (we measure pre-projection
    drift via one Euclidean step)."""
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    x0, y0 = _init()
    batches = _batches()
    drgda = DRGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.05, eta=0.2))
    s1 = drgda.init(x0, y0, batches)
    step1 = drgda.make_step(donate=False)
    for _ in range(30):
        s1, _ = step1(s1, batches)
    # feasibility never needs re-projection for DRGDA:
    assert float(M.stiefel_error(s1.x["w"]).max()) < 1e-4


def test_metric_components_nonnegative_and_decrease():
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    opt = DRGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.05, eta=0.2))
    x0, y0 = _init()
    batches = _batches()
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    ms = []
    for t in range(120):
        state, _ = step(state, batches)
        if t % 40 == 39:
            m = convergence_metric(prob, state.x, state.y, batches)
            assert all(float(v) >= 0 for v in m.values())
            ms.append(float(m["M_t"]))
    assert ms[-1] <= ms[0]
