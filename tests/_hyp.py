"""Hypothesis shim: use the real library when installed, otherwise a tiny
deterministic fallback so the property tests still *run* (with seeded random
examples) instead of failing collection.

Only the strategy surface this suite uses is implemented: ``st.integers`` and
``st.composite``.  The fallback draws ``max_examples`` examples from
``random.Random(0)``, so failures reproduce exactly across runs.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # sample(rng) -> value

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def composite(fn):
            def build(*args, **kwargs):
                def sample(rng):
                    return fn(lambda strat: strat.sample(rng), *args, **kwargs)
                return _Strategy(sample)
            return build

    st = _Strategies()

    def settings(**kwargs):
        max_examples = kwargs.get("max_examples", 10)

        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            def wrapper():
                rng = random.Random(0)
                for _ in range(getattr(fn, "_max_examples", 10)):
                    fn(*(s.sample(rng) for s in strategies))
            # pytest must NOT see the wrapped test's params as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
