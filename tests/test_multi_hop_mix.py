"""Fused multi-hop mix megakernel: oracle, kernel, and backend contracts.

Coverage:

* the halo-panel oracle reproduces the stacked ``mix_ring`` ground truth
  exactly (center rows of a circularly gathered panel == k exact hops);
* Pallas-interpret == oracle **bitwise under jit** on ragged / prime
  shapes for both the fp32 and the int8 all-hop variants;
* under 8 forced devices the fused shard_map mix stays **bit-identical**
  to the stacked backend for k in {1, 3, 5} (including chunked
  ``fuse_depth``), and the all-hop int8 schedule agrees across backends
  to FMA rounding (every hop decodes identical int8 values; only the
  final combines' contraction differs);
* structurally, one fused mix step lowers to ONE ``pallas_call`` per leaf
  where the unfused schedule launches k (asserted on the jaxpr with
  ``REPRO_KERNEL_IMPL=pallas_interpret``);
* the CommEngine ``quant_hops="all"`` round is backend-independent.

The multi-device tests skip on the single-CPU tier-1 run and are driven by
``test_multi_hop_under_8_forced_devices`` in a subprocess (same pattern as
``test_mix_backend_equiv.py``).
"""
import functools
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import CommEngine, CommSpec
from repro.comms.backend import ShardMapBackend, StackedBackend
from repro.comms.compress import quantize_det
from repro.core.gossip import GossipSpec
from repro.kernels import ops, ref

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

WC, WS = 1.0 / 3.0, 1.0 / 3.0


def _mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices())[:8].reshape(8), ("node",))


def _x(n, f=427, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, f), jnp.float32)


def _bit_equal(a, b):
    assert a.shape == b.shape and a.dtype == b.dtype
    assert bool(jnp.all(a == b)), \
        f"max |diff| = {float(jnp.max(jnp.abs(a - b)))}"


# ---------------------------------------------------------------------------
# oracle == stacked ground truth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,b,hops", [(16, 2, 3), (16, 4, 1), (12, 3, 5)])
def test_ref_oracle_matches_stacked_ring_mix(n, b, hops):
    """Center rows of the circularly gathered halo panel after ``hops``
    fused combines == the stacked backend's ``W^hops`` rows.  This is the
    math check (halo absorbs all edge garbage), so tight allclose — the two
    programs have different shapes and contract FMA differently; the bitwise
    contract lives in the same-shaped jitted backend comparisons below."""
    spec = GossipSpec(topology="ring", n_nodes=n, self_weight=WC)
    x = _x(n)
    want = StackedBackend().mix(spec, x, hops)
    halo = hops
    for i0 in range(0, n, b):
        rows = [(i0 + j) % n for j in range(-halo, b + halo)]
        panel = x[np.asarray(rows)]
        got = ref.multi_hop_mix_ref(panel, hops=hops, out_rows=b, halo=halo,
                                    w_self=WC, w_side=(1.0 - WC) / 2.0)
        np.testing.assert_allclose(np.asarray(want[i0:i0 + b]),
                                   np.asarray(got), rtol=1e-6, atol=1e-6)


def test_halo_must_cover_hops():
    with pytest.raises(AssertionError):
        ops.multi_hop_mix(_x(8), hops=3, out_rows=2, halo=2,
                          w_self=WC, w_side=WS)


# ---------------------------------------------------------------------------
# kernel (interpret) == oracle, bitwise under jit, ragged shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,halo,hops,f", [
    (2, 3, 3, 257),      # prime-ish lane tail
    (1, 5, 5, 64),       # single-row block, deep schedule
    (4, 1, 1, 1024),     # aligned fast case
    (3, 5, 3, 130),      # halo > hops, ragged
])
def test_fp32_kernel_bitwise_vs_oracle(b, halo, hops, f):
    panel = jax.random.normal(jax.random.PRNGKey(1), (b + 2 * halo, f),
                              jnp.float32)
    run = lambda impl: jax.jit(functools.partial(
        ops.multi_hop_mix, hops=hops, out_rows=b, halo=halo,
        w_self=WC, w_side=WS, impl=impl))(panel)
    _bit_equal(run("ref"), run("pallas_interpret"))


@pytest.mark.parametrize("b,halo,hops,f", [
    (22, 5, 5, 512),     # tile-aligned: rows=32, f%128==0 — bitwise
    (2, 3, 3, 257),      # ragged: padding shifts FMA contraction ~1 ulp
    (1, 2, 2, 64),
])
def test_quant_kernel_vs_oracle(b, halo, hops, f):
    """Tile-aligned panels (rows%32==0, f%128==0) run the identical program
    unpadded vs padded, so kernel == oracle bitwise under jit.  Ragged
    panels go through a padded program whose FMA contraction can differ by
    1 ulp at the lane boundary; a boundary-riding element may requantize
    one int8 step apart, so assert within one quantization ulp instead."""
    rows = b + 2 * halo
    x = jax.random.normal(jax.random.PRNGKey(2), (rows, f), jnp.float32)
    q, s = quantize_det(x)
    run = lambda impl: jax.jit(functools.partial(
        ops.multi_hop_mix_quant, hops=hops, out_rows=b, halo=halo,
        w_self=WC, w_side=WS, impl=impl))(q, s)
    a, bb = run("ref"), run("pallas_interpret")
    if rows % 32 == 0 and f % 128 == 0:
        _bit_equal(a, bb)
    else:
        tol = float(jnp.max(jnp.abs(a))) / 127.0
        assert float(jnp.max(jnp.abs(a - bb))) <= tol


def test_estimates_registered_and_recorded():
    from repro.obs import estimates as est
    assert "multi_hop_mix" in est.KERNELS
    assert "multi_hop_mix_quant" in est.KERNELS
    panel = _x(8, f=256)
    with est.collect() as c:
        ops.multi_hop_mix(panel, hops=3, out_rows=2, halo=3,
                          w_self=WC, w_side=WS)
    rec = c.snapshot()["multi_hop_mix"]
    expect = est.multi_hop_mix_est(8, 256, hops=3, out_rows=2)
    assert rec["ops"] == expect.ops == 4.0 * 3 * 8 * 256
    assert rec["mem"] == expect.mem
    # the quant estimate accounts int8 inputs + revisiting-grid state traffic
    eq = est.multi_hop_mix_est(8, 256, hops=3, out_rows=2, quant=True)
    assert eq.lds > eq.mem > 0
    assert eq.ops > expect.ops


# ---------------------------------------------------------------------------
# 8-device backend equivalence
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("n", [16, 32])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_fused_mix_bit_identical(n, k):
    """The acceptance-criterion bit-identity: fused halo-panel megakernel ==
    unfused hop-by-hop == stacked roll mixing, to the bit, jitted fp32."""
    spec = GossipSpec(topology="ring", n_nodes=n, self_weight=WC)
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 61, 7), jnp.float32)
    want = jax.jit(lambda t: StackedBackend().mix(spec, t, k))(x)
    for kw in ({"fuse": "on"}, {"fuse": "off"}, {"fuse": "on",
                                                 "fuse_depth": 2}):
        sm = ShardMapBackend(_mesh(), **kw)
        got = jax.jit(lambda t: sm.mix(spec, t, k))(x)
        _bit_equal(want, got)


@multi_device
@pytest.mark.parametrize("n", [16, 32])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_quant_all_hop_schedule_across_backends(n, k):
    """Every hop of the all-hop int8 schedule decodes identical payloads on
    both backends; results agree to FMA rounding of the combines (rel 1e-5
    is ~100x looser than the observed few-ulp gap, ~50x tighter than one
    int8 quantization step)."""
    spec = GossipSpec(topology="ring", n_nodes=n, self_weight=WC)
    x = jax.random.normal(jax.random.PRNGKey(4), (n, 61, 7), jnp.float32)
    st = StackedBackend()
    want = jax.jit(lambda t: st.quant_ring_hops(spec, t, k))(x)
    tol = 1e-5 * float(jnp.max(jnp.abs(want)))
    for kw in ({"fuse": "on"}, {"fuse": "off"}, {"fuse": "on",
                                                 "fuse_depth": 2}):
        sm = ShardMapBackend(_mesh(), **kw)
        got = jax.jit(lambda t: sm.quant_ring_hops(spec, t, k))(x)
        assert float(jnp.max(jnp.abs(want - got))) <= tol


@multi_device
def test_one_pallas_call_per_fused_mix(monkeypatch, assert_jaxpr_rule):
    """Structural acceptance check: with the kernel dispatch forced on, a
    fused k=3 mix lowers to ONE pallas_call where the unfused schedule
    launches one per hop.  (Same coverage as the old hand-rolled regex
    asserts, via the repro.analysis comm-schedule rule — which counts
    kernel CALL SITES by wrapper name because the jaxpr printer dedups
    identical jitted sub-jaxprs.)"""
    monkeypatch.setenv("REPRO_KERNEL_IMPL", "pallas_interpret")
    spec = GossipSpec(topology="ring", n_nodes=32, self_weight=WC)
    x = _x(32)   # b = 4 rows/device: the unfused interior combine is real
    kernels = ("multi_hop_mix", "ring_mix")

    def mix(**kw):
        return lambda t: ShardMapBackend(_mesh(), **kw).mix(spec, t, 3)

    # ONE megakernel launch for k=3, one halo ppermute per side
    fused = assert_jaxpr_rule("comm-schedule", name="fused", fn=mix(fuse="on"),
                              args=(x,), expect_kernel_calls=1,
                              expect_ppermute=2, kernel_names=kernels)
    assert "pallas_call" in str(fused) and "multi_hop_mix" in str(fused)
    # one combine kernel + one exchange pair per hop on the unfused path
    assert_jaxpr_rule("comm-schedule", name="unfused", fn=mix(fuse="off"),
                      args=(x,), expect_kernel_calls=3, expect_ppermute=6,
                      kernel_names=kernels)
    # chunked launches: ceil(3/2) = 2 megakernel calls
    assert_jaxpr_rule("comm-schedule", name="chunked",
                      fn=mix(fuse="on", fuse_depth=2), args=(x,),
                      expect_kernel_calls=2, kernel_names=kernels)


@multi_device
def test_engine_quant_all_hops_across_backends():
    """Full EF-int8 CommEngine round with quant_hops="all": the consensus
    update is backend-independent, and the static wire accounting knows the
    tail hops shipped int8."""
    comm = CommSpec(compressor="int8", gamma=0.9, quant_hops="all")
    spec = GossipSpec(topology="ring", n_nodes=16, self_weight=WC, comm=comm)
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 61, 7), jnp.float32)
    outs = {}
    for name, be in (("stacked", StackedBackend()),
                     ("fused", ShardMapBackend(_mesh(), fuse="on")),
                     ("unfused", ShardMapBackend(_mesh(), fuse="off"))):
        eng = CommEngine(spec, backend=be)
        cs = eng.init_state({"x": x})
        out, _ = jax.jit(lambda c, t: eng.mix(c, "x", t, steps=3, rnd=2))(
            cs, x)
        outs[name] = out
        wire, raw = eng.wire_round_bytes(x, 3)
        assert wire < raw
        # tail hops are int8 + one f32 scale per row — far below the fp32
        # hat hops that quant_hops="first" would ship
        comm_first = CommSpec(compressor="int8", gamma=0.9)
        eng_first = CommEngine(
            GossipSpec(topology="ring", n_nodes=16, self_weight=WC,
                       comm=comm_first), backend=be)
        wire_first, _ = eng_first.wire_round_bytes(x, 3)
        assert wire < wire_first
    tol = 1e-5 * float(jnp.max(jnp.abs(outs["stacked"])))
    for name in ("fused", "unfused"):
        assert float(jnp.max(jnp.abs(outs["stacked"] - outs[name]))) <= tol


# ---------------------------------------------------------------------------
# subprocess driver: force 8 host devices and run the matrix above
# ---------------------------------------------------------------------------


def test_multi_hop_under_8_forced_devices():
    if len(jax.devices()) >= 8:
        pytest.skip("already multi-device; in-process tests cover this")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "not forced_devices"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(REPO, "tests"))
    assert out.returncode == 0, \
        (out.stdout[-3000:] + "\n" + out.stderr[-2000:])
    assert "skipped" not in out.stdout.splitlines()[-1] or \
        " 0 skipped" in out.stdout.splitlines()[-1]
