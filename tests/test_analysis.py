"""repro.analysis: lint rules, kernel checker, contracts, sentinels.

Covers the engine itself (every rule fires on a seeded bad fixture and
stays quiet on a good one), the satellite regressions (optimizer/serve
weak-type sweeps, the ServeEngine prefill-bucket recompile sentinel, the
doubly-stochastic channel sweep), and the CLI selftest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (Finding, LintTarget, RecompileError,
                            RecompileSentinel, RULES, contracts,
                            kernel_check, lint)
from repro.analysis import entrypoints
from repro.launch import roofline


# ---------------------------------------------------------------------------
# jaxpr lint rules
# ---------------------------------------------------------------------------


def test_weak_type_leak_fires_and_passes():
    bad = {"y": jnp.full((4, 3), 0.5), "x": jnp.zeros((4, 2))}
    findings = RULES["weak-type-leak"](LintTarget(name="t", state=bad))
    assert len(findings) == 1 and "'y'" in findings[0].where
    good = jax.tree.map(lambda l: l.astype(l.dtype), bad)
    assert not RULES["weak-type-leak"](LintTarget(name="t", state=good))


def test_weak_type_dtype_whitelist():
    state = {"q": jnp.zeros((2,), jnp.int8), "s": jnp.zeros((2,))}
    assert not RULES["weak-type-leak"](
        LintTarget(name="t", state=state), allowed_dtypes=("int8", "float32"))
    findings = RULES["weak-type-leak"](
        LintTarget(name="t", state=state), allowed_dtypes=("float32",))
    assert len(findings) == 1 and "int8" in findings[0].message


def test_effect_in_quiet_path_fires(assert_jaxpr_rule):
    from jax.experimental import io_callback

    def noisy(x):
        io_callback(lambda a: None, None, x)
        return x + 1

    with pytest.raises(AssertionError, match="effect"):
        assert_jaxpr_rule("effect-in-quiet-path", fn=noisy,
                          args=(jnp.zeros((2,)),))
    assert_jaxpr_rule("effect-in-quiet-path", fn=lambda x: x + 1,
                      args=(jnp.zeros((2,)),))


def test_donation_miss_fires_on_collapsed_buffers(assert_jaxpr_rule):
    # two donated leaves, one output of that aval: one donation must miss
    def collapse(state):
        return state["a"] + state["b"]

    args = ({"a": jnp.zeros((4, 4)), "b": jnp.zeros((4, 4))},)
    with pytest.raises(AssertionError, match="donation-miss"):
        assert_jaxpr_rule("donation-miss", fn=collapse, args=args,
                          donate_argnums=(0,))

    # carried-state shape: every donated leaf reappears as an output
    def carry(state):
        return {"a": state["a"] * 2, "b": state["b"] + 1}

    assert_jaxpr_rule("donation-miss", fn=carry, args=args,
                      donate_argnums=(0,))


def test_comm_schedule_counts(assert_jaxpr_rule):
    # a plain matmul trips the forbidden-primitive check ...
    f = lambda a: a @ a
    args = (jnp.zeros((4, 4)),)
    with pytest.raises(AssertionError, match="dot_general"):
        assert_jaxpr_rule("comm-schedule", fn=f, args=args,
                          forbid_primitives=("dot_general",))
    # ... and elementwise code passes it
    assert_jaxpr_rule("comm-schedule", fn=lambda a: a + a, args=args,
                      forbid_primitives=("dot_general",))


def test_iter_eqns_descends_into_scan():
    from repro.analysis import count_primitive

    def f(x):
        return jax.lax.scan(lambda c, _: (jnp.sin(c), None), x, None,
                            length=3)[0]

    cj = jax.make_jaxpr(f)(jnp.zeros((2,)))
    assert count_primitive(cj, "sin") == 1     # inside the scan body


def test_lint_multi_rule_dispatch():
    target = LintTarget(name="t", state={"y": jnp.full((2,), 0.5)},
                        jaxpr=jax.make_jaxpr(lambda x: x + 1)(jnp.zeros(2)))
    findings = lint(target, ["weak-type-leak", "effect-in-quiet-path"])
    assert [f.rule for f in findings] == ["weak-type-leak"]


# ---------------------------------------------------------------------------
# RecompileSentinel
# ---------------------------------------------------------------------------


def test_sentinel_wrap_counts_traces():
    s = RecompileSentinel()
    f = s.wrap(lambda x: x * 2, label="double")
    f(jnp.zeros((2,)))
    f(jnp.ones((2,)))          # same shape: cached, no retrace
    s.check(max_traces=1)
    f(jnp.zeros((3,)))         # new shape: retrace
    assert s.traces("double") == 2
    with pytest.raises(RecompileError, match="double"):
        s.check(max_traces=1)


def test_sentinel_watch_existing_jitted():
    s = RecompileSentinel()
    g = jax.jit(lambda x: x + 1)
    g(jnp.zeros((2,)))
    s.watch("g", g)            # baseline snapshot: 1 compile already done
    g(jnp.ones((2,)))
    s.check(max_traces=0)      # no growth since the snapshot
    g(jnp.zeros((5,)))
    with pytest.raises(RecompileError):
        s.check(max_traces=0)


def test_sentinel_watch_rejects_plain_functions():
    with pytest.raises(TypeError):
        RecompileSentinel().watch("f", lambda x: x)


# ---------------------------------------------------------------------------
# kernel checker
# ---------------------------------------------------------------------------


def test_vmem_budget_clean_on_registered_configs():
    assert kernel_check.check_vmem(roofline.get_hardware("tpu_v5e")) == []


def test_vmem_budget_fires_on_oversized_block():
    findings = kernel_check.vmem_findings(
        "multi_hop_mix", {"block_f": 1 << 21},
        dims={"rows": 64, "out_rows": 32})
    assert findings and findings[0].rule == "vmem-budget"
    assert "exceeds" in findings[0].message


def test_vmem_footprint_scales_with_config():
    small = kernel_check.vmem_footprint("ring_mix", {}, {"block_rows": 8})
    big = kernel_check.vmem_footprint("ring_mix", {}, {"block_rows": 512})
    assert big == 64 * small


def test_vmem_footprint_unknown_kernel():
    with pytest.raises(KeyError, match="no footprint model"):
        kernel_check.vmem_footprint("nope", {}, {})


def test_tiling_contracts_clean():
    assert kernel_check.check_tiling() == []


def test_oracle_coverage_clean():
    assert kernel_check.check_oracle_coverage() == []


def test_oracle_coverage_fires_on_missing_oracle(tmp_path):
    # a dispatched kernel with estimates but no ref.* call and no tune entry
    bad = tmp_path / "ops.py"
    bad.write_text(
        "def rogue_kernel(x):\n"
        "    _est.record('rogue', None)\n"
        "    _tune.lookup('rogue', (1,), 'float32')\n"
        "    return x\n")
    findings = kernel_check.check_oracle_coverage(bad)
    msgs = "\n".join(f.message for f in findings)
    assert "no ref.py oracle" in msgs
    assert "estimates.KERNELS" in msgs
    assert "tune.DEFAULTS" in msgs


# ---------------------------------------------------------------------------
# numerical contracts
# ---------------------------------------------------------------------------


def test_matrix_findings_fire_on_substochastic():
    from repro.core.gossip import ring_matrix
    w = np.asarray(ring_matrix(6)) * 0.9
    findings = contracts.matrix_findings(w, where="scaled")
    assert any("row sums" in f.message for f in findings)
    assert not contracts.matrix_findings(np.asarray(ring_matrix(6)))


def test_matrix_findings_fire_on_asymmetry():
    w = np.asarray([[0.6, 0.4], [0.3, 0.7]])
    findings = contracts.matrix_findings(w)
    assert any("asymmetric" in f.message for f in findings)


@pytest.mark.parametrize("schedule", ["static", "round_robin", "matching"])
@pytest.mark.parametrize("drop,straggle", [(0.3, 0.0), (0.0, 0.3),
                                           (0.25, 0.25)])
def test_faulty_channels_stay_doubly_stochastic(schedule, drop, straggle):
    """Satellite: every ChannelModel edge schedule keeps effective W_t
    doubly stochastic across 100 seeded rounds."""
    from repro.comms.channel import ChannelModel
    from repro.core.gossip import ring_matrix
    ch = ChannelModel(np.asarray(ring_matrix(8), np.float32),
                      schedule=schedule, drop_rate=drop,
                      straggler_rate=straggle)
    assert contracts.doubly_stochastic_findings(ch, rounds=100) == []


def test_channel_sweep_clean():
    assert contracts.channel_sweep_findings(rounds=5) == []


def test_doubly_stochastic_fires_on_leaky_channel():
    class Leaky:
        def w_t(self, rnd, key):
            from repro.core.gossip import ring_matrix
            return jnp.asarray(ring_matrix(4), jnp.float32) * 0.95

    findings = contracts.doubly_stochastic_findings(Leaky(), rounds=2)
    assert findings and findings[0].rule == "doubly-stochastic"


def test_manifold_feasibility_clean():
    assert contracts.manifold_findings() == []


# ---------------------------------------------------------------------------
# entry points + satellites
# ---------------------------------------------------------------------------


def test_all_optimizer_inits_strongly_typed():
    """Satellite: weak-type-leak over all five optimizer families' inits."""
    assert entrypoints.pass_optimizer_state(None) == []


def test_optimizer_donations_alias():
    assert entrypoints.pass_optimizer_donation(None) == []


def test_quiet_paths_effect_free():
    assert entrypoints.pass_quiet_path(None) == []


def test_replica_group_strong_even_from_weak_params():
    """Satellite regression: ReplicaGroup must strong-cast while stacking —
    jnp.stack preserves weak_type from user-supplied params."""
    from repro.serve.replica import ReplicaGroup
    weak = {"embed": jnp.full((4, 8), 0.5),
            "scale": jnp.float32(2.0) * jnp.ones((3,))}
    assert any(l.weak_type for l in jax.tree.leaves(weak))   # fixture is bad
    rg = ReplicaGroup(weak, n_replicas=2)
    assert not RULES["weak-type-leak"](
        LintTarget(name="replica", state=rg.params))
    assert not RULES["weak-type-leak"](
        LintTarget(name="replica.comm", state=rg.state))


def test_selftest_catches_all_fixtures():
    assert entrypoints.selftest() == []


def test_cli_exits_clean_and_writes_summary(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "analysis.json"
    # restrict to the cheap self-contained passes: kernel + contract checks
    rc = main(["--rules", "vmem-budget", "tiling", "oracle-coverage",
               "doubly-stochastic", "manifold-feasibility",
               "--json", str(out)])
    assert rc == 0
    import json
    summary = json.loads(out.read_text())
    assert summary["n_findings"] == 0
    assert set(summary["passes"]) == {"kernels", "contracts"}


# ---------------------------------------------------------------------------
# serve prefill-bucket sentinel (satellite)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_model():
    from repro import configs
    from repro.models import transformer as T
    cfg = configs.get_config("smollm-135m", smoke=True)
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


def test_prefill_compiles_once_per_bucket(smoke_model):
    """Satellite: the page-bucketed prefill jit cache compiles exactly once
    per page bucket, never per request."""
    from repro.serve import PagedKVSpec, ServeEngine
    cfg, params = smoke_model
    spec = PagedKVSpec(page_size=8, n_pages=32, max_pages_per_slot=4)
    eng = ServeEngine(cfg, params, kv_spec=spec, n_slots=2)
    sentinel = RecompileSentinel()

    rng = np.random.default_rng(0)
    buckets_seen = set()
    # prompt lengths spanning two buckets (<=8 -> 1 page, 9..16 -> 2 pages),
    # several requests per bucket
    for i, length in enumerate([3, 8, 5, 9, 16, 12, 2, 11]):
        prompt = rng.integers(1, cfg.vocab_size, size=length).tolist()
        npg = spec.pages_for(length)
        pages = list(range(1 + 4 * (i % 2), 1 + 4 * (i % 2) + npg))
        eng.admit(i % 2, prompt, pages)
        buckets_seen.add(npg * spec.page_size)
        eng.step()
        eng.release(i % 2)

    assert buckets_seen == {8, 16}
    assert set(eng._prefill_fns) == buckets_seen       # one fn per bucket
    sentinel.watch("decode_step", eng._step)    # compiled once by now
    for cache_len, fn in eng._prefill_fns.items():
        sentinel.watch(f"prefill[{cache_len}]", fn)
        assert fn._cache_size() == 1, (cache_len, fn._cache_size())
    # replay the same workload: nothing may recompile
    for i, length in enumerate([6, 10, 8, 15]):
        prompt = rng.integers(1, cfg.vocab_size, size=length).tolist()
        npg = spec.pages_for(length)
        eng.admit(i % 2, prompt, list(range(1, 1 + npg)))
        eng.step()
        eng.release(i % 2)
    sentinel.check(max_traces=0)


# ---------------------------------------------------------------------------
# Finding plumbing
# ---------------------------------------------------------------------------


def test_finding_str_and_json():
    f = Finding("r", "w", "m")
    assert str(f) == "[r] w: m"
    assert f.to_json() == {"rule": "r", "where": "w", "message": "m"}
