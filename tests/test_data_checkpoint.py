"""Data pipeline determinism/heterogeneity + checkpoint roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data.synthetic import ClassificationStream, TokenStream


def test_classification_stream_deterministic_and_heterogeneous():
    s1 = ClassificationStream(n_nodes=4, batch_per_node=64, seed=7)
    s2 = ClassificationStream(n_nodes=4, batch_per_node=64, seed=7)
    b1, b2 = s1.batch(3), s2.batch(3)
    np.testing.assert_array_equal(b1["images"], b2["images"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert b1["images"].shape == (4, 64, 14, 14, 1)
    # different nodes see different label mixtures (heterogeneity)
    hists = np.stack([np.bincount(b1["labels"][i], minlength=3)
                      for i in range(4)])
    assert hists.std(axis=0).sum() > 0
    # different steps differ
    assert not np.array_equal(b1["images"], s1.batch(4)["images"])


def test_token_stream_group_conditional():
    s = TokenStream(n_nodes=2, batch_per_node=8, seq_len=64, vocab_size=101,
                    n_groups=4, seed=1)
    b = s.batch(0)
    assert b["tokens"].shape == (2, 8, 64)
    assert b["group_ids"].shape == (2, 8)
    assert b["tokens"].max() < 101 and b["tokens"].min() >= 0
    # same group => similar unigram support; different groups differ
    toks, gids = b["tokens"].reshape(-1, 64), b["group_ids"].reshape(-1)
    if len(set(gids[:2])) == 2:
        h0 = np.bincount(toks[0], minlength=101)
        h1 = np.bincount(toks[1], minlength=101)
        assert (h0 * h1).sum() < (h0 * h0).sum()  # weak separation


def test_token_stream_multicodebook():
    s = TokenStream(n_nodes=1, batch_per_node=2, seq_len=16, vocab_size=33,
                    n_codebooks=4)
    assert s.batch(0)["tokens"].shape == (1, 2, 16, 4)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 10, tree)
    checkpoint.save(d, 20, tree)
    assert checkpoint.latest_step(d) == 20
    like = jax.tree.map(jnp.zeros_like, tree)
    back = checkpoint.restore(d, 10, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch(tmp_path):
    d = str(tmp_path / "ckpt")
    checkpoint.save(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError, match="structure mismatch"):
        checkpoint.restore(d, 1, {"z": jnp.zeros(3)})
