"""Model-zoo correctness: chunked cores vs sequential oracles; prefill vs
token-by-token decode for every block family; MoE dispatch vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (AttnSpec, BlockSpec, FrontendSpec, ModelConfig,
                                MoESpec, SSMSpec, XLSTMSpec, patterned_stages,
                                uniform_stages)
from repro.models import moe, ssm, transformer as T, xlstm

TOKS = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
GLOB = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))


def _decode_matches_forward(cfg, params, toks, fe=None, n_steps=3, atol=2e-5):
    lp, _, caches = T.forward(params, cfg, toks, mode="prefill",
                              cache_len=toks.shape[1] + n_steps + 1,
                              frontend_embeds=fe)
    cur = toks
    ld = lp[:, -1]
    errs = []
    for t in range(n_steps):
        nxt = jnp.argmax(ld, -1)
        cur = jnp.concatenate(
            [cur, nxt[:, None] if cfg.n_codebooks == 1 else nxt[:, None, :]], 1)
        lf, _, _ = T.forward(params, cfg, cur, frontend_embeds=fe)
        pos = jnp.full((toks.shape[0],), toks.shape[1] + t, jnp.int32)
        ld, caches = T.decode_step(params, cfg, nxt, pos, caches,
                                   frontend_embeds=fe)
        errs.append(float(jnp.abs(ld - lf[:, -1]).max()))
    assert max(errs) < atol, errs


def test_ssd_chunked_vs_sequential():
    B, S, H, P, N = 2, 100, 4, 16, 8       # non-multiple of chunk
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    b_ = jax.random.normal(ks[1], (B, S, H, N))
    c_ = jax.random.normal(ks[2], (B, S, H, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    la = -dt * jnp.exp(jax.random.normal(ks[4], (B, S, H)) * 0.5)
    y1, h1 = ssm._ssd_chunked(x, b_, c_, dt, la, 32)
    y0, h0 = ssm.ssd_reference(x, b_, c_, dt, la)
    np.testing.assert_allclose(y1, y0, atol=1e-4)
    np.testing.assert_allclose(h1, h0, atol=1e-4)


def test_mlstm_chunked_vs_sequential():
    B, S, H, D = 2, 72, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q, k, v = (jax.random.normal(kk, (B, S, H, D)) for kk in ks[:3])
    li = jax.random.normal(ks[3], (B, S, H)) * 2.0
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    y1, (c1, n1, m1) = xlstm._mlstm_chunked(q, k, v, li, lf, 16)
    y0, (c0, n0, m0) = xlstm.mlstm_reference(q, k, v, li, lf)
    np.testing.assert_allclose(y1, y0, atol=2e-3)
    np.testing.assert_allclose(c1, c0, atol=1e-4)
    np.testing.assert_allclose(m1, m0, atol=1e-5)


def test_moe_dispatch_matches_dense_oracle():
    cfg = ModelConfig(d_model=32, d_ff=64)
    spec = MoESpec(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                   capacity_factor=2.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, aux = moe.apply_moe(params, x, spec)

    t = 32
    xf = x.reshape(t, 32)
    probs = jax.nn.softmax(xf @ params["router"], -1)
    gv, gi = jax.lax.top_k(probs, spec.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    y_ref = jnp.zeros((t, 32))
    for e in range(spec.n_experts):
        h = jax.nn.silu(xf @ params["w_gate"][e]) * (xf @ params["w_up"][e])
        wsel = jnp.sum(jnp.where(gi == e, gv, 0.0), -1)
        y_ref += (h @ params["w_down"][e]) * wsel[:, None]
    sh = params["shared"]
    y_ref += (jax.nn.silu(xf @ sh["w_gate"]) * (xf @ sh["w_up"])) @ sh["w_down"]
    np.testing.assert_allclose(y, y_ref.reshape(2, 16, 32), atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor << 1 some tokens overflow and are dropped, but
    output stays finite and shared experts still serve every token."""
    cfg = ModelConfig(d_model=16, d_ff=32)
    spec = MoESpec(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                   capacity_factor=0.25)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    y, _ = moe.apply_moe(params, x, spec)
    assert bool(jnp.isfinite(y).all())


def test_dense_swa_decode():
    local = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa", sliding_window=8))
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=97, stages=patterned_stages(
                          [local, local, GLOB], 6), remat=False)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    _decode_matches_forward(cfg, params, TOKS)


def test_hybrid_mamba_decode():
    mb = BlockSpec(kind="mamba", ssm=SSMSpec(d_state=8, head_dim=16, chunk=16))
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=97, stages=patterned_stages([mb, mb, GLOB], 6),
                      remat=False)
    params = T.init_params(jax.random.PRNGKey(6), cfg)
    _decode_matches_forward(cfg, params, TOKS, atol=5e-5)


def test_xlstm_decode():
    xs = XLSTMSpec(chunk=16)
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
                      vocab_size=97,
                      stages=patterned_stages(
                          [BlockSpec(kind="mlstm", xlstm=xs),
                           BlockSpec(kind="slstm", xlstm=xs)], 4),
                      remat=False)
    params = T.init_params(jax.random.PRNGKey(7), cfg)
    _decode_matches_forward(cfg, params, TOKS, atol=5e-5)


def test_mla_decode():
    mla = BlockSpec(kind="attn", attn=AttnSpec(
        kind="mla", q_lora_rank=32, kv_lora_rank=16, qk_rope_head_dim=8,
        qk_nope_head_dim=16, v_head_dim=16))
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=97, stages=uniform_stages(mla, 4), remat=False)
    params = T.init_params(jax.random.PRNGKey(9), cfg)
    _decode_matches_forward(cfg, params, TOKS)


def test_vlm_cross_attention_uses_image():
    xa = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa", cross_attn=True))
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=97, stages=patterned_stages([GLOB, xa], 4),
                      frontend=FrontendSpec(kind="vision", n_tokens=12,
                                            embed_dim=48),
                      remat=False)
    params = T.init_params(jax.random.PRNGKey(2), cfg)
    fe1 = jax.random.normal(jax.random.PRNGKey(3), (2, 12, 48))
    fe2 = jax.random.normal(jax.random.PRNGKey(4), (2, 12, 48))
    l1, _, _ = T.forward(params, cfg, TOKS, frontend_embeds=fe1)
    l2, _, _ = T.forward(params, cfg, TOKS, frontend_embeds=fe2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4   # image actually matters
    _decode_matches_forward(cfg, params, TOKS, fe=fe1)


def test_audio_multicodebook():
    cfg = ModelConfig(d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=33, n_codebooks=4,
                      stages=uniform_stages(GLOB, 4), remat=False)
    params = T.init_params(jax.random.PRNGKey(4), cfg)
    ta = jax.random.randint(jax.random.PRNGKey(5), (2, 16, 4), 0, 33)
    logits, _, _ = T.forward(params, cfg, ta)
    assert logits.shape == (2, 16, 4, 33)
    _decode_matches_forward(cfg, params, ta)


def test_remat_grads_match_no_remat():
    import dataclasses
    cfg = ModelConfig(d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                      vocab_size=50, stages=uniform_stages(GLOB, 4),
                      remat=True)
    params = T.init_params(jax.random.PRNGKey(10), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 12), 0, 50)

    def loss(p, c):
        lg, aux, _ = T.forward(p, c, toks)
        oh = jax.nn.one_hot(toks, 50)
        return -jnp.mean(jnp.sum(jax.nn.log_softmax(lg) * oh, -1)) + aux

    g1 = jax.grad(loss)(params, cfg)
    g2 = jax.grad(loss)(params, dataclasses.replace(cfg, remat=False))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(a, b, atol=1e-5)
