"""Per-architecture smoke tests (REQUIRED): instantiate the REDUCED variant
of each assigned architecture and run one forward + one decentralized
minimax train step on CPU, asserting output shapes and no NaNs.  Also one
serve_step decode against a fresh cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import TokenStream
from repro.launch.steps import build_trainer, init_train_state, make_serve_step
from repro.models import transformer as T

N_NODES = 2
BPN = 2
SEQ = 32


def _batch(cfg):
    stream = TokenStream(n_nodes=N_NODES, batch_per_node=BPN, seq_len=SEQ,
                         vocab_size=cfg.vocab_size, n_groups=cfg.n_groups,
                         n_codebooks=cfg.n_codebooks, seed=0)
    b = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    if cfg.frontend is not None:
        b["frontend_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(0),
            (N_NODES, BPN, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    return b


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512

    # --- forward shape check -------------------------------------------
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tok_shape = (2, SEQ) if cfg.n_codebooks == 1 else (2, SEQ, cfg.n_codebooks)
    toks = jax.random.randint(jax.random.PRNGKey(1), tok_shape, 0,
                              cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (2, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    logits, aux, _ = T.forward(params, cfg, toks, frontend_embeds=fe)
    want = (2, SEQ, cfg.vocab_size) if cfg.n_codebooks == 1 else \
        (2, SEQ, cfg.n_codebooks, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # --- one DRSGDA train step -----------------------------------------
    opt, problem = build_trainer(cfg, N_NODES, optimizer="drsgda")
    batch = _batch(cfg)
    state = init_train_state(jax.random.PRNGKey(3), cfg, opt, N_NODES, batch)
    state, metrics = opt.step(state, batch)
    assert np.isfinite(float(metrics.loss))
    assert np.isfinite(float(metrics.grad_norm_x))
    # params keep their structure/shapes and stay finite
    for a, b in zip(jax.tree.leaves(state.x), jax.tree.leaves(state.u)):
        assert a.shape == b.shape
        assert bool(jnp.isfinite(a.astype(jnp.float32)).all())
    # Stiefel leaves stay feasible after the retraction step
    from repro.core.minimax import validate_manifold
    assert float(validate_manifold(
        jax.tree.map(lambda l: l[0], state.x), problem.manifold_map)) < 1e-3
    # at least one leaf is manifold-constrained for attention archs
    n_stiefel = sum(bool(m) for m in jax.tree.leaves(problem.stiefel_mask))
    if cfg.family != "ssm":
        assert n_stiefel > 0
    else:
        assert n_stiefel > 0  # xlstm: mlstm wq/wk/wv/w_down leaves


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_smoke_serve_decode(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = T.init_cache(cfg, b, SEQ)
    tok = jnp.zeros((b,) if cfg.n_codebooks == 1 else (b, cfg.n_codebooks),
                    jnp.int32)
    pos = jnp.full((b,), SEQ - 1, jnp.int32)
    fe = None
    if cfg.frontend is not None:
        fe = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (b, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    step = make_serve_step(cfg)
    logits, new_cache = step(params, tok, pos, cache, frontend_embeds=fe)
    want = (b, cfg.vocab_size) if cfg.n_codebooks == 1 else \
        (b, cfg.n_codebooks, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_long_context_override_transforms_all_full_attention():
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        if not configs.needs_long_context_override(cfg):
            continue
        cfg2 = configs.long_context_override(cfg)
        assert not configs.needs_long_context_override(cfg2)
        # native windows are preserved (gemma3 locals keep 1024)
        if arch == "gemma3-27b":
            wins = {b.attn.sliding_window for st in cfg2.stages
                    for b in st.blocks}
            assert 1024 in wins and configs.LONG_CONTEXT_WINDOW in wins


def test_all_full_configs_have_exact_card_dims():
    card = {
        "deepseek-v2-236b": (60, 5120, 128, 102400),
        "gemma3-27b": (62, 5376, 32, 262144),
        "granite-3-2b": (40, 2048, 32, 49155),
        "granite-3-8b": (40, 4096, 32, 49155),
        "zamba2-2.7b": (54, 2560, 32, 32000),
        "llama-3.2-vision-11b": (40, 4096, 32, 128256),
        "smollm-135m": (30, 576, 9, 49152),
        "musicgen-large": (48, 2048, 32, 2048),
        "granite-moe-1b-a400m": (24, 1024, 16, 49155),
        "xlstm-1.3b": (48, 2048, 4, 50304),
    }
    for arch, (nl, d, h, v) in card.items():
        cfg = configs.get_config(arch)
        assert cfg.n_layers == nl, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == h, arch
        assert cfg.vocab_size == v, arch
