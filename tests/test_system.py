"""End-to-end system tests: real multi-step decentralized minimax training
on CPU (reduced configs), serving loop, and the launchers' CLIs."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.metric import convergence_metric
from repro.data.synthetic import TokenStream
from repro.launch.serve import generate
from repro.launch.steps import build_trainer, init_train_state
from repro.models import transformer as T


def test_end_to_end_decentralized_lm_training_loss_decreases():
    """Train the reduced smollm with DRSGDA for 30 steps: loss must drop,
    consensus must hold, Stiefel leaves must stay feasible."""
    cfg = configs.get_config("smollm-135m", smoke=True)
    n_nodes, bpn, seq = 4, 4, 32
    opt, problem = build_trainer(cfg, n_nodes, optimizer="drsgda")
    stream = TokenStream(n_nodes, bpn, seq, cfg.vocab_size,
                         n_groups=cfg.n_groups, seed=0)

    def to_jax(b):
        return {k: jnp.asarray(v) for k, v in b.items()}

    batch0 = to_jax(stream.batch(0))
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt, n_nodes, batch0)
    step = opt.make_step(donate=True)
    losses = []
    for t in range(30):
        state, metrics = step(state, to_jax(stream.batch(t + 1)))
        losses.append(float(metrics.loss))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    m = convergence_metric(problem, state.x, state.y,
                           to_jax(stream.batch(99)))
    assert float(m["stiefel_residual"]) < 1e-3
    # adversary moved off uniform (groups genuinely differ)
    y_bar = np.asarray(state.y).mean(0)
    assert np.abs(y_bar - 1.0 / cfg.n_groups).max() > 1e-4


def test_drgda_vs_baseline_on_lm_smoke():
    """Both DRGDA and GT-GDA improve the deterministic objective; DRGDA
    keeps feasibility without re-projection."""
    cfg = configs.get_config("granite-3-2b", smoke=True)
    n_nodes = 2
    stream = TokenStream(n_nodes, 4, 32, cfg.vocab_size,
                         n_groups=cfg.n_groups, seed=1)
    full = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}

    results = {}
    for name in ("drgda", "gt-gda"):
        opt, problem = build_trainer(cfg, n_nodes, optimizer=name)
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt, n_nodes,
                                 full)
        step = opt.make_step(donate=False)
        first = last = None
        for t in range(15):
            state, metrics = step(state, full)
            if first is None:
                first = float(metrics.loss)
            last = float(metrics.loss)
        results[name] = (first, last)
    for name, (first, last) in results.items():
        assert last < first, (name, first, last)


def test_generate_loop_all_token_kinds():
    for arch in ("smollm-135m", "musicgen-large", "llama-3.2-vision-11b",
                 "xlstm-1.3b"):
        cfg = configs.get_config(arch, smoke=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        shape = (2, 8) if cfg.n_codebooks == 1 else (2, 8, cfg.n_codebooks)
        prompt = jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                    cfg.vocab_size)
        fe = None
        if cfg.frontend is not None:
            fe = 0.1 * jax.random.normal(
                jax.random.PRNGKey(2),
                (2, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
        toks = generate(cfg, params, prompt, 4, frontend_embeds=fe,
                        temperature=0.0)
        assert toks.shape[:2] == (2, 4)
        assert int(toks.max()) < cfg.vocab_size


def test_train_cli_smoke(capsys):
    from repro.launch import train as train_cli
    rc = train_cli.main([
        "--arch", "smollm-135m", "--smoke", "--steps", "6", "--nodes", "2",
        "--batch-per-node", "2", "--seq-len", "32", "--eval-every", "3"])
    assert rc == 0
    out = capsys.readouterr().out.strip().splitlines()
    rows = [json.loads(l) for l in out if l.startswith("{")]
    assert rows and np.isfinite(rows[-1]["loss"])


def test_serve_cli_smoke(capsys):
    from repro.launch import serve as serve_cli
    rc = serve_cli.main(["--arch", "smollm-135m", "--smoke", "--batch", "2",
                         "--prompt-len", "8", "--new-tokens", "4"])
    assert rc == 0
