"""Observability subsystem tests.

Coverage, per the subsystem's contracts:

* obs on/off trajectories are **bit-identical** (counters never feed back
  into the math), including runs where the io_callback flush fires;
* the threaded wire counters agree with the analytical byte oracle
  (``backend.est_hop_bytes`` / ``CommEngine.wire_round_bytes``) within 1%;
* the JSONL event log validates against the checked-in schema, and
  malformed events are rejected;
* the Chrome-trace/Perfetto export round-trips;
* ``kernels/ops.py`` dispatch records analytical Estimates;
* ``launch/roofline.py`` hardware models resolve via env/explicit name and
  ``place()`` classifies compute- vs memory-bound correctly;
* importing ``launch/perf.py`` never clobbers ``XLA_FLAGS`` (satellite
  regression test);
* ``benchmarks/run.py`` summary records append with parsed metrics.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import CommSpec
from repro.core import manifolds as M
from repro.core.gda import DRGDA, DRSGDA, GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.minimax import MinimaxProblem, project_simplex
from repro.obs import (Telemetry, WireCounters, estimates as obs_est,
                       events as obs_events, unpack)
from repro.obs.telemetry import read_counter_series
from repro.obs.trace import Trace

D, R, G, N_NODES = 10, 2, 3, 6
RHO = 1.0
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_problem(seed=0):
    a = np.stack([np.random.RandomState(seed + i).randn(D, D)
                  for i in range(G)])
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)

    def loss_fn(x, y, batch):
        ag = a + batch
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return jnp.dot(y, lg) - RHO * jnp.sum((y - 1.0 / G) ** 2)

    return MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          manifold_map={"w": "stiefel"})


def _init(seed=5):
    x0 = broadcast_to_nodes(
        {"w": M.random_stiefel(jax.random.PRNGKey(seed), D, R)}, N_NODES)
    y0 = jnp.full((N_NODES, G), 1.0 / G)
    return x0, y0


def _batches(seed=6, scale=0.1):
    return scale * jax.random.normal(jax.random.PRNGKey(seed),
                                     (N_NODES, G, D, D))


def _run(opt, steps=6):
    x0, y0 = _init()
    batches = _batches()
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    for _ in range(steps):
        state, m = step(state, batches)
    jax.block_until_ready(m.loss)
    return state


# ---------------------------------------------------------------------------
# bit-identity + flush cadence
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cls", [DRGDA, DRSGDA])
def test_trajectory_bit_identical_obs_on_off(cls, tmp_path):
    """Jitted trajectories with telemetry on (flushes firing) and off agree
    bit for bit — the counters never touch the update math."""
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    tel = Telemetry(run="bit", out_dir=str(tmp_path), flush_every=3)
    s_off = _run(cls(prob, spec, GDAHyper()))
    s_on = _run(cls(prob, spec, GDAHyper(), telemetry=tel))
    for leaf_on, leaf_off in zip(
            jax.tree.leaves((s_on.x, s_on.y, s_on.u, s_on.v)),
            jax.tree.leaves((s_off.x, s_off.y, s_off.u, s_off.v))):
        assert bool((leaf_on == leaf_off).all())
    # flushes really fired: call 1 plus every 3rd call
    steps_flushed = [ev["step"] for ev in read_counter_series(tel.events_path)]
    assert steps_flushed == [1, 3, 6]


def test_counters_cumulative_and_monotone(tmp_path):
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    tel = Telemetry(run="mono", out_dir=str(tmp_path), flush_every=2)
    _run(DRGDA(prob, spec, GDAHyper()), steps=6)  # obs-off runs stay clean
    _run(DRGDA(prob, spec, GDAHyper(), telemetry=tel), steps=6)
    rows = read_counter_series(tel.events_path)
    assert [r["step"] for r in rows] == [1, 2, 4, 6]
    for key in WireCounters._fields:
        series = [r["data"][key] for r in rows]
        assert series == sorted(series), key


# ---------------------------------------------------------------------------
# wire accounting vs the analytical oracle
# ---------------------------------------------------------------------------


def test_wire_counters_match_hop_oracle(tmp_path):
    """bytes/hop from the threaded counters == the hop-weighted mean of the
    backend's est_hop_bytes over DRGDA's four mixed slots, within 1%."""
    prob = _make_problem()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    tel = Telemetry(run="oracle", out_dir=str(tmp_path), flush_every=100)
    opt = DRGDA(prob, spec, GDAHyper(), telemetry=tel)
    steps = 4
    state = _run(opt, steps=steps)
    obs = unpack(state.obs)
    x0, y0 = _init()
    k = opt.k
    assert obs.rounds == steps * 4               # x, y, u, v per step
    assert obs.hops == steps * (3 * k + 1)       # x/y/u at k hops, v at 1
    assert obs.dropped_links == 0.0
    per_slot = {s: opt.backend.est_hop_bytes(spec, t) for s, t in
                (("x", x0), ("y", y0), ("u", x0), ("v", y0))}
    hops = {"x": k, "y": k, "u": k, "v": 1}
    expect = sum(per_slot[s] * hops[s] for s in hops) / sum(hops.values())
    got = obs.wire_bytes / obs.hops
    assert abs(got - expect) / expect < 0.01
    assert obs.wire_bytes == obs.raw_bytes       # engine-less: no compression


def test_wire_counters_compressed_engine(tmp_path):
    """Under an int8 CommEngine the wire bytes track wire_round_bytes —
    strictly below raw, and matching the engine's own accounting within 1%."""
    prob = _make_problem()
    comm = CommSpec(compressor="int8", gamma=0.9)
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, comm=comm)
    tel = Telemetry(run="comp", out_dir=str(tmp_path), flush_every=100)
    opt = DRGDA(prob, spec, GDAHyper(), telemetry=tel)
    steps = 3
    state = _run(opt, steps=steps)
    obs = unpack(state.obs)
    x0, y0 = _init()
    k = opt.k
    expect_wire = expect_raw = 0.0
    for tree, hops in ((x0, k), (y0, k), (x0, k), (y0, 1)):   # x, y, u, v
        w, r = opt.engine.wire_round_bytes(tree, hops)
        expect_wire += float(w)
        expect_raw += float(r)
    assert abs(obs.wire_bytes - steps * expect_wire) / (steps * expect_wire) \
        < 0.01
    assert abs(obs.raw_bytes - steps * expect_raw) / (steps * expect_raw) \
        < 0.01
    # compression strictly helps, modestly here: multi-hop rounds still ship
    # k-1 full-precision hat hops (exactly what _gossip_hats executes)
    assert obs.wire_bytes < obs.raw_bytes


def test_wire_counters_quant_all_hops(tmp_path):
    """quant_hops="all": the k-1 tail hops ship int8 payloads (+ one f32
    scale per row), so the counters must follow est_quant_hop_bytes for the
    tail — strictly below the quant_hops="first" wire, still matching the
    engine's own accounting within 1%."""
    prob = _make_problem()
    comm = CommSpec(compressor="int8", gamma=0.9, quant_hops="all")
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, comm=comm)
    tel = Telemetry(run="allhop", out_dir=str(tmp_path), flush_every=100)
    opt = DRGDA(prob, spec, GDAHyper(), telemetry=tel)
    steps = 3
    state = _run(opt, steps=steps)
    obs = unpack(state.obs)
    x0, y0 = _init()
    k = opt.k
    assert k > 1, "multi-hop gossip required to exercise the tail hops"
    eng_first = DRGDA(prob, GossipSpec(topology="ring", n_nodes=N_NODES,
                                       comm=CommSpec(compressor="int8",
                                                     gamma=0.9)),
                      GDAHyper()).engine
    expect_wire = expect_first = 0.0
    for tree, hops in ((x0, k), (y0, k), (x0, k), (y0, 1)):   # x, y, u, v
        w, _ = opt.engine.wire_round_bytes(tree, hops)
        wf, _ = eng_first.wire_round_bytes(tree, hops)
        expect_wire += float(w)
        expect_first += float(wf)
        # the tail accounting really is the int8 oracle
        if hops > 1:
            per_tail = opt.engine.backend.est_quant_hop_bytes(
                opt.engine.gossip, tree)
            per_fp32 = opt.engine.backend.est_hop_bytes(
                opt.engine.gossip, tree)
            assert per_tail < per_fp32
            assert abs((w - wf) - (hops - 1) * (per_tail - per_fp32)) < 1e-6
    assert expect_wire < expect_first
    assert abs(obs.wire_bytes - steps * expect_wire) / (steps * expect_wire) \
        < 0.01
    assert obs.wire_bytes < obs.raw_bytes


# ---------------------------------------------------------------------------
# event log + schema
# ---------------------------------------------------------------------------


def test_event_log_validates_and_rejects_malformed(tmp_path):
    tel = Telemetry(run="ev", out_dir=str(tmp_path))
    tel.event("dashboard", {"M_t": 1.0}, step=10)
    n = obs_events.validate_log(tel.events_path)
    assert n == 2                                 # meta + dashboard
    with pytest.raises(ValueError):               # unknown type enum
        obs_events.make_event("bogus_type", "ev", {})
    with pytest.raises(ValueError):               # data must be an object
        obs_events.validate_event(
            {"type": "counters", "ts": 0.0, "run": "ev", "data": 3})
    with pytest.raises(ValueError):               # missing required field
        obs_events.validate_event({"type": "counters", "ts": 0.0, "run": "ev"})
    bad = tmp_path / "bad.events.jsonl"
    bad.write_text(json.dumps({"type": "span", "run": "ev", "data": {}})
                   + "\n")                        # no ts
    with pytest.raises(ValueError):
        obs_events.validate_log(str(bad))


def test_dashboard_streams_metric_components(tmp_path):
    prob = _make_problem()
    tel = Telemetry(run="dash", out_dir=str(tmp_path))
    x0, y0 = _init()
    ev = tel.dashboard(prob, x0, y0, _batches(), step=7, extra={"loss": 1.5})
    data = ev["data"]
    for key in ("M_t", "grad_norm", "consensus_x", "loss"):
        assert key in data, key
    assert "w" in data["drift"]                   # per-leaf cross-node drift
    assert obs_events.validate_log(tel.events_path) == 2


# ---------------------------------------------------------------------------
# trace round-trip
# ---------------------------------------------------------------------------


def test_trace_perfetto_roundtrip(tmp_path):
    tr = Trace(run="rt")
    with tr.span("outer", step=1):
        with tr.span("inner"):
            pass
    tr.instant("marker")
    tr.counter("wire", {"wire_bytes": 123.0})
    payload = tr.to_chrome_trace()
    assert payload["otherData"]["run"] == "rt"
    phases = sorted(e["ph"] for e in payload["traceEvents"])
    assert phases == ["C", "X", "X", "i"]
    spans = {e["name"]: e for e in payload["traceEvents"] if e["ph"] == "X"}
    assert spans["inner"]["dur"] <= spans["outer"]["dur"]
    path = tr.save(str(tmp_path / "t.trace.json"))
    rt = Trace.load(path)
    assert rt.run == "rt"
    assert rt.events == tr.events


# ---------------------------------------------------------------------------
# kernel estimates
# ---------------------------------------------------------------------------


def test_ops_dispatch_records_estimates():
    from repro.kernels import ops

    x = M.random_stiefel(jax.random.PRNGKey(0), 32, 4)
    g = jax.random.normal(jax.random.PRNGKey(1), (32, 4))
    with obs_est.collect() as c:
        jax.block_until_ready(ops.stiefel_project(x, g))
        jax.block_until_ready(ops.fused_retract(x, 0.1 * g))
    snap = c.snapshot()
    assert set(snap) == {"stiefel_project", "fused_retract"}
    expect = obs_est.stiefel_project_est(32, 4)
    rec = snap["stiefel_project"]
    assert rec["calls"] == 1
    assert rec["ops"] == expect.ops
    assert rec["mem"] == expect.mem
    assert rec["intensity"] == pytest.approx(expect.intensity)
    # under jit the wrapper records once per trace, not per execution
    f = jax.jit(lambda a, b: ops.stiefel_project(a, b))
    with obs_est.collect() as c2:
        for _ in range(5):
            jax.block_until_ready(f(x, g))
    assert c2.snapshot()["stiefel_project"]["calls"] == 1


def test_estimates_algebra():
    e = obs_est.Estimates(ops=100.0, lds=20.0, mem=10.0)
    assert (e + e).ops == 200.0
    assert e.scaled(3).mem == 30.0
    assert e.intensity == 10.0
    assert set(obs_est.KERNELS) == {"flash_attention", "stiefel_project",
                                    "fused_retract", "ring_mix", "quant_mix",
                                    "multi_hop_mix", "multi_hop_mix_quant",
                                    "paged_decode"}


# ---------------------------------------------------------------------------
# hardware models + roofline placement
# ---------------------------------------------------------------------------


def test_hardware_model_selection(monkeypatch):
    from repro.launch import roofline

    monkeypatch.delenv("REPRO_HW", raising=False)
    assert roofline.get_hardware().name == "tpu_v5e"
    monkeypatch.setenv("REPRO_HW", "tpu_v4")
    assert roofline.get_hardware().name == "tpu_v4"
    assert roofline.get_hardware("tpu_v5p").name == "tpu_v5p"  # explicit wins
    with pytest.raises(ValueError):
        roofline.get_hardware("tpu_v9000")
    hw = roofline.HARDWARE["tpu_v5e"]
    assert roofline.PEAK_FLOPS == hw.peak_flops    # legacy constants track


def test_roofline_place_classifies_bound():
    from repro.launch import roofline

    hw = roofline.get_hardware("tpu_v5e")
    hot = obs_est.Estimates(ops=1e12, lds=1e6, mem=1e6)     # high intensity
    cold = obs_est.Estimates(ops=1e6, lds=1e9, mem=1e9)     # streaming
    assert roofline.place(hot, hw)["bound"] == "compute"
    assert roofline.place(cold, hw)["bound"] == "memory"
    p = roofline.place(cold, hw)
    assert p["attainable_flops"] == pytest.approx(hw.hbm_bw * cold.intensity)
    assert p["time_s"] == pytest.approx(cold.ops / p["attainable_flops"])


# ---------------------------------------------------------------------------
# satellites: perf.py XLA_FLAGS + BENCH_summary
# ---------------------------------------------------------------------------


def test_perf_import_does_not_clobber_xla_flags():
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.path.join(_REPO_ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "import os, repro.launch.perf; print(repr(os.environ.get('XLA_FLAGS')))"],
        capture_output=True, text=True, env=env, timeout=120)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "None"


def test_perf_dryrun_flags_respect_user_env(monkeypatch):
    from repro.launch import perf

    monkeypatch.setenv("XLA_FLAGS", "--user_set=1")
    assert perf._set_dryrun_xla_flags() == "--user_set=1"
    monkeypatch.delenv("XLA_FLAGS")
    monkeypatch.setenv("REPRO_DRYRUN_XLA_FLAGS", "--custom=2")
    assert perf._set_dryrun_xla_flags() == "--custom=2"
    monkeypatch.delenv("XLA_FLAGS")
    monkeypatch.delenv("REPRO_DRYRUN_XLA_FLAGS")
    assert perf._set_dryrun_xla_flags() == perf.DEFAULT_DRYRUN_XLA_FLAGS


def test_bench_summary_append(tmp_path, monkeypatch):
    from benchmarks import run as bench_run

    path = tmp_path / "BENCH_summary.json"
    monkeypatch.setattr(bench_run, "SUMMARY_PATH", str(path))
    bench_run.append_summary("obs", 123.4,
                             "overhead_pct=3.21;bit_identical=True", rev="abc")
    bench_run.append_summary("mix", 9.9, "hps=100.5", rev="abc")
    rows = json.loads(path.read_text())
    assert [r["name"] for r in rows] == ["obs", "mix"]
    assert rows[0]["metrics"]["overhead_pct"] == 3.21
    assert rows[0]["metrics"]["bit_identical"] == "True"
    assert rows[0]["git_rev"] == "abc"
    assert rows[0]["us_per_call"] == 123.4
    assert "timestamp" in rows[0]
