import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# real single CPU device; only the dry-run subprocess forces 512 devices.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def assert_jaxpr_rule():
    """The repro.analysis lint engine as a test assertion: trace a function
    (or take a jaxpr/state) and fail with the findings if a rule fires."""
    from repro.analysis import assert_jaxpr_rule as check
    return check
