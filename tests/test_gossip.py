"""Gossip/consensus substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import gossip as G

SET = dict(deadline=None, max_examples=15)


@pytest.mark.parametrize("topology,n", [
    ("ring", 3), ("ring", 8), ("ring", 20), ("full", 5), ("torus", 12),
    ("star", 6),
])
def test_mixing_matrix_doubly_stochastic(topology, n):
    w = G.mixing_matrix(topology, n)
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(w, w.T, atol=1e-12)
    assert (w >= 0).all()
    assert G.second_largest_eigenvalue(w) < 1.0


@given(st.integers(3, 16), st.integers(1, 5), st.integers(0, 1000))
@settings(**SET)
def test_ring_mix_matches_dense(n, steps, seed):
    w = jnp.asarray(G.ring_matrix(n), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 7))
    dense = G.mix_dense(w, x, steps=steps)
    ring = G.mix_ring(x, steps=steps)
    np.testing.assert_allclose(dense, ring, atol=1e-5)


@given(st.integers(2, 16), st.integers(0, 1000))
@settings(**SET)
def test_mixing_preserves_mean(n, seed):
    """W doubly stochastic => gossip preserves the average (the consensus
    invariant the decentralized analysis leans on)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 5))
    spec = G.GossipSpec(topology="ring", n_nodes=n, k_steps=3)
    mixed = spec.mix(x)
    np.testing.assert_allclose(jnp.mean(mixed, 0), jnp.mean(x, 0), atol=1e-5)


def test_gossip_contracts_to_consensus():
    n = 12
    x = jax.random.normal(jax.random.PRNGKey(0), (n, 4))
    spec = G.GossipSpec(topology="ring", n_nodes=n)
    var0 = float(jnp.var(x, axis=0).sum())
    x200 = spec.mix(x, steps=200)
    var200 = float(jnp.var(x200, axis=0).sum())
    assert var200 < 1e-6 * max(var0, 1e-9)


def test_theorem1_k_prescription():
    for n in (4, 16, 20, 64):
        w = G.ring_matrix(n)
        k = G.required_gossip_steps(w, n)
        lam = G.second_largest_eigenvalue(w)
        assert lam ** k <= 1.0 / (2.0 * np.sqrt(n)) + 1e-12
        # minimality: one fewer step violates the bound
        if k > 1:
            assert lam ** (k - 1) > 1.0 / (2.0 * np.sqrt(n))


def test_mix_pytree_and_small_n():
    tree = {"a": jnp.ones((1, 3)), "b": jnp.arange(8.0).reshape(2, 4)}
    spec1 = G.GossipSpec(topology="ring", n_nodes=1, k_steps=4)
    out1 = spec1.mix({"a": tree["a"]})
    np.testing.assert_allclose(out1["a"], tree["a"])
    # self_weight is honored for n == 2: 0.5 gives full averaging,
    # and matrix/mix_ring agree for any other weight.
    spec2 = G.GossipSpec(topology="ring", n_nodes=2, k_steps=1, self_weight=0.5)
    out2 = spec2.mix({"b": tree["b"]})
    np.testing.assert_allclose(out2["b"][0], tree["b"].mean(0), atol=1e-6)
    spec2w = G.GossipSpec(topology="ring", n_nodes=2, k_steps=1, self_weight=0.7)
    np.testing.assert_allclose(
        spec2w.mix({"b": tree["b"]})["b"],
        G.mix_dense(jnp.asarray(spec2w.matrix, jnp.float32), tree["b"]),
        atol=1e-6)


def test_ring_mix_kernel_matches_gossip_hop():
    """kernels.ops.ring_mix == one hop of mix_ring on the local view."""
    from repro.kernels import ops
    n = 6
    x = jax.random.normal(jax.random.PRNGKey(3), (n, 5, 4))
    hop = G.mix_ring(x, steps=1)
    left = jnp.roll(x, 1, axis=0)
    right = jnp.roll(x, -1, axis=0)
    fused = ops.ring_mix(x, left, right, w_self=1 / 3, w_side=1 / 3,
                         impl="pallas_interpret")
    np.testing.assert_allclose(hop, fused, atol=1e-6)
