"""Elastic asynchronous gossip: churn, staleness, and the typed comms API.

Covers the execution-mode acceptance criteria:

* elastic off (static full membership, clean channel) => the optimizer
  builds the *same program* as main (no engine), so trajectories are
  bit-identical on both backends;
* ``tau = 0`` realizes bit-for-bit the simulation ``ChannelModel``'s drop
  semantics (same key schedule, same mask algebra, same backend
  expressions);
* n=2 ring with one departure degenerates to identity mixing and leaves
  the survivor untouched;
* rejoin is deterministic under a fixed seed, and a rejoined node's x is
  re-initialized feasibly (consensus mean projected through the manifold);
* every realized W_t stays symmetric doubly stochastic over the live
  subgraph (contracts validator);
* the ``repro.comms.api`` facade: Protocols + the backend string registry;
* the ``stiefel_mask`` legacy path warns exactly once and derives the same
  maps; ``TrainSpec`` reproduces the keyword ``build_trainer`` wiring.

The shard_map tests skip below 8 devices and are re-run in a forced-device
subprocess (same pattern as test_mix_backend_equiv), so tier-1 covers them.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms.backend import ShardMapBackend, StackedBackend
from repro.comms.elastic import (ChurnSchedule, ElasticEngine, ElasticSpec,
                                 Membership)
from repro.comms.layer import CommEngine, maybe_engine
from repro.comms.spec import CommSpec
from repro.core.gossip import GossipSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices())[:8].reshape(8), ("node",))


def _assert_bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert bool(jnp.all(x == y)), \
            f"max |diff| = {float(jnp.max(jnp.abs(x - y)))}"


def _assert_close(a, b, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=0)


def _x(n, d=6, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d), jnp.float32)


# ---------------------------------------------------------------------------
# spec plumbing
# ---------------------------------------------------------------------------


def test_elastic_spec_enabled_gating():
    assert not ElasticSpec().enabled
    assert not ElasticSpec(churn=ChurnSchedule()).enabled
    assert ElasticSpec(straggler_rate=0.1).enabled
    assert ElasticSpec(drop_rate=0.1).enabled
    assert ElasticSpec(churn=ChurnSchedule(
        kind="scripted", events=((1, "leave", 0),))).enabled
    assert ElasticSpec(churn=ChurnSchedule(kind="random")).enabled


def test_disabled_elastic_builds_no_engine():
    """Static full membership + clean channel => maybe_engine falls through,
    so the compiled program is the exact pre-elastic one by construction."""
    g = GossipSpec(topology="ring", n_nodes=4, elastic=ElasticSpec())
    assert maybe_engine(g) is None
    g2 = GossipSpec(topology="ring", n_nodes=4)
    assert maybe_engine(g2) is None


def test_elastic_rejects_simulation_channel():
    comm = CommSpec(drop_rate=0.3)
    g = GossipSpec(topology="ring", n_nodes=4, comm=comm,
                   elastic=ElasticSpec(straggler_rate=0.2))
    with pytest.raises(ValueError, match="ElasticSpec"):
        ElasticEngine(g)


def test_membership_is_a_state_leaf():
    g = GossipSpec(topology="ring", n_nodes=4,
                   elastic=ElasticSpec(straggler_rate=0.2))
    eng = ElasticEngine(g)
    st = eng.init_state({"x": _x(4)})
    assert isinstance(st.elastic, Membership)
    leaves = jax.tree.leaves(st)
    assert any(l.shape == (4,) for l in leaves)  # the active mask rides along
    # the traced transition is committed once per round across slots
    st2 = eng.init_state({"x": _x(4), "y": _x(4)})
    _, st2 = eng.mix(st2, "x", _x(4), steps=1, rnd=0)
    r_after_x = int(st2.elastic.round)
    _, st2 = eng.mix(st2, "y", _x(4), steps=1, rnd=0)
    assert int(st2.elastic.round) == r_after_x == 0
    np.testing.assert_array_equal(np.asarray(st2.elastic.prev_active),
                                  np.ones(4))


# ---------------------------------------------------------------------------
# churn schedules
# ---------------------------------------------------------------------------


def test_scripted_schedule_timeline():
    churn = ChurnSchedule(kind="scripted",
                          events=((2, "leave", 1), (5, "join", 1)))
    act = jnp.ones((4,), jnp.float32)
    key = jax.random.PRNGKey(0)
    masks = [np.asarray(churn.active(act, r, key)) for r in range(7)]
    assert masks[0][1] == 1 and masks[1][1] == 1
    assert masks[2][1] == 0 and masks[4][1] == 0          # left at round 2
    assert masks[5][1] == 1 and masks[6][1] == 1          # rejoined at 5
    assert all(m[[0, 2, 3]].all() for m in masks)         # others untouched


def test_random_schedule_is_seeded_and_pins_node0():
    churn = ChurnSchedule(kind="random", leave_rate=0.5, join_rate=0.5)
    act = jnp.ones((8,), jnp.float32)
    key = jax.random.PRNGKey(3)
    a = np.asarray(churn.active(act, 4, key))
    b = np.asarray(churn.active(act, 4, key))
    np.testing.assert_array_equal(a, b)                   # same key, same draw
    draws = np.stack([np.asarray(churn.active(act, r, jax.random.fold_in(
        key, r))) for r in range(32)])
    assert (draws[:, 0] == 1).all()                       # node 0 pinned live
    assert draws.min() == 0                               # someone does leave


# ---------------------------------------------------------------------------
# tau = 0: bit-for-bit the simulation channel's drop semantics
# ---------------------------------------------------------------------------


def _tau0_pair(n=8, backend=None):
    comm = CommSpec(drop_rate=0.2, straggler_rate=0.4)
    sim = CommEngine(GossipSpec(topology="ring", n_nodes=n, comm=comm),
                     backend=backend)
    ela = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=n,
        elastic=ElasticSpec(tau=0, drop_rate=0.2, straggler_rate=0.4)),
        backend=backend)
    return sim, ela


def test_tau0_bit_identical_to_channel_drops_stacked():
    sim, ela = _tau0_pair()
    x = _x(8)
    st_s, st_e = sim.init_state({"x": x}), ela.init_state({"x": x})
    z_s = z_e = x
    for rnd in range(20):
        z_s, st_s = sim.mix(st_s, "x", z_s, steps=1, rnd=rnd)
        z_e, st_e = ela.mix(st_e, "x", z_e, steps=1, rnd=rnd)
        _assert_bit_equal(z_s, z_e)


@multi_device
def test_tau0_bit_identical_to_channel_drops_shard_map():
    backend = ShardMapBackend(_mesh(), axis="node")
    sim, ela = _tau0_pair(backend=backend)
    x = _x(8)
    st_s, st_e = sim.init_state({"x": x}), ela.init_state({"x": x})
    sim_step = jax.jit(lambda st, z, r: sim.mix(st, "x", z, steps=1, rnd=r))
    ela_step = jax.jit(lambda st, z, r: ela.mix(st, "x", z, steps=1, rnd=r))
    z_s = z_e = x
    for rnd in range(10):
        z_s, st_s = sim_step(st_s, z_s, rnd)
        z_e, st_e = ela_step(st_e, z_e, rnd)
        _assert_bit_equal(z_s, z_e)


@multi_device
def test_elastic_wt_application_equal_across_backends():
    """The same realized W_t applied by mix_wt must agree between the
    stacked einsum and the shard_map per-link ring path (same tolerance
    as the existing cross-backend channel tests: summation order differs)."""
    spec = GossipSpec(topology="ring", n_nodes=8, k_steps=1)
    ela = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=8,
        elastic=ElasticSpec(churn=ChurnSchedule(kind="random",
                                                leave_rate=0.3))))
    st = ela.init_state({"x": _x(8)})
    wt = ela.realized_wt(st, "x", 5)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 33, 7)),
            "b": jax.random.normal(jax.random.PRNGKey(4), (8, 129))}
    stk, shm = StackedBackend(), ShardMapBackend(_mesh(), axis="node")
    a = jax.jit(lambda t, w: stk.mix_wt(spec, t, w))(tree, wt)
    b = jax.jit(lambda t, w: shm.mix_wt(spec, t, w))(tree, wt)
    _assert_close(a, b, atol=1e-6)


def test_equivalence_under_8_forced_devices():
    if len(jax.devices()) >= 8:
        pytest.skip("already multi-device; in-process tests cover this")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "shard_map or across_backends"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(REPO, "tests"))
    assert out.returncode == 0, \
        (out.stdout[-3000:] + "\n" + out.stderr[-2000:])


# ---------------------------------------------------------------------------
# departures, staleness, rejoin
# ---------------------------------------------------------------------------


def test_n2_ring_departure_is_identity_round():
    """Degenerate case: one of two nodes leaves — the realized W_t is the
    identity and the survivor's value passes through unchanged."""
    churn = ChurnSchedule(kind="scripted", events=((1, "leave", 1),))
    eng = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=2, elastic=ElasticSpec(churn=churn)))
    x = _x(2)
    st = eng.init_state({"x": x})
    z, st = eng.mix(st, "x", x, steps=1, rnd=0)     # both live: real mix
    assert not bool(jnp.all(z == x))
    wt = np.asarray(eng.realized_wt(st, "x", 1))
    np.testing.assert_array_equal(wt, np.eye(2, dtype=np.float32))
    z2, st = eng.mix(st, "x", z, steps=1, rnd=1)    # node 1 gone: identity
    np.testing.assert_array_equal(np.asarray(z2[0]), np.asarray(z[0]))


def test_departed_rows_are_identity_and_wt_doubly_stochastic():
    churn = ChurnSchedule(kind="scripted", events=((0, "leave", 2),
                                                   (0, "leave", 5)))
    eng = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=8,
        elastic=ElasticSpec(churn=churn, drop_rate=0.2)))
    st = eng.init_state({"x": _x(8)})
    wt = np.asarray(eng.realized_wt(st, "x", 0))
    np.testing.assert_allclose(wt.sum(0), 1.0, atol=1e-6)
    np.testing.assert_allclose(wt.sum(1), 1.0, atol=1e-6)
    np.testing.assert_allclose(wt, wt.T, atol=0)
    for i in (2, 5):
        np.testing.assert_array_equal(wt[i], np.eye(8, dtype=wt.dtype)[i])


def test_stale_hop_tolerance_keeps_links_alive():
    """With every node straggling, tau=0 freezes gossip (W_t = I) while
    tau>=1 keeps mixing against the last-received buffers."""
    x = _x(8)
    frozen = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=8,
        elastic=ElasticSpec(tau=0, straggler_rate=1.0)))
    st = frozen.init_state({"x": x})
    z, st = frozen.mix(st, "x", x, steps=1, rnd=0)
    _assert_bit_equal(z, x)                              # nobody published

    tol = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=8,
        elastic=ElasticSpec(tau=2, straggler_rate=1.0)))
    st = tol.init_state({"x": x})
    z, st = tol.mix(st, "x", x, steps=1, rnd=0)
    assert not bool(jnp.all(z == x))                     # stale mixing ran
    # beyond tau the links age out and gossip freezes again
    for rnd in range(1, 5):
        z_prev = z
        z, st = tol.mix(st, "x", z, steps=1, rnd=rnd)
    _assert_bit_equal(z, z_prev)


def test_rejoin_reinit_consensus_mean_and_determinism():
    """A rejoining node's x is replaced by its live neighbours' consensus
    mean (projected through the registered manifold); two runs with the
    same seed are bit-identical."""
    from repro import geometry
    churn = ChurnSchedule(kind="scripted",
                          events=((1, "leave", 3), (3, "join", 3)))

    def run():
        eng = ElasticEngine(GossipSpec(
            topology="ring", n_nodes=6,
            elastic=ElasticSpec(churn=churn, seed=11)))
        eng.register_manifolds({"x": "stiefel"})
        key = jax.random.PRNGKey(0)
        x = jax.vmap(lambda k: geometry.get("stiefel").rand(k, 8, 2))(
            jax.random.split(key, 6))
        st = eng.init_state({"x": x})
        z = x
        for rnd in range(3):                       # node 3 leaves at rnd 3
            z, st = eng.mix(st, "x", z, steps=1, rnd=rnd)
        # at the join round, the rejoined slot is replaced by the live
        # neighbours' consensus mean projected through the manifold —
        # inspect the reinit itself, before that round's convex mixing
        # (which, like any gossip hop, only the next retraction re-feasifies)
        view = eng.round_view(st, "x", 3)
        reinit = np.asarray(eng._reinit_joined("x", z, view))
        z4, st = eng.mix(st, "x", z, steps=1, rnd=3)
        z5, st = eng.mix(st, "x", z4, steps=1, rnd=4)
        return reinit, np.asarray(z5)

    (ra, a), (rb, b) = run(), run()
    np.testing.assert_array_equal(a, b)            # fixed seed => bit-equal
    np.testing.assert_array_equal(ra, rb)
    w = ra[3]                                      # feasible consensus mean
    assert np.abs(w.T @ w - np.eye(2)).max() < 1e-5


def test_compressed_elastic_hats_gate_on_publish():
    """In compressed mode the CHOCO hats are the stale buffers: a joining
    node's hat resets to zero, non-publishers' hats stay put."""
    comm = CommSpec(compressor="int8", error_feedback=True, gamma=0.8)
    churn = ChurnSchedule(kind="scripted",
                          events=((1, "leave", 2), (2, "join", 2)))
    eng = ElasticEngine(GossipSpec(
        topology="ring", n_nodes=4, comm=comm,
        elastic=ElasticSpec(churn=churn, tau=1)))
    x = _x(4)
    st = eng.init_state({"x": x})
    z = x
    for rnd in range(4):
        z, st = eng.mix(st, "x", z, steps=1, rnd=rnd)
        assert np.isfinite(np.asarray(z)).all()
    # round 2 was the join: node 2's hat restarted from zero then folded
    # exactly one payload; all nodes' hats stay finite
    assert np.isfinite(np.asarray(st.hats["x"])).all()


def test_contracts_elastic_sweep_is_clean():
    from repro.analysis.contracts import elastic_sweep_findings
    assert elastic_sweep_findings(rounds=25) == []


# ---------------------------------------------------------------------------
# wire counters: only live links count
# ---------------------------------------------------------------------------


def test_wire_counters_count_only_live_links():
    from repro.obs import wire
    churn = ChurnSchedule(kind="scripted", events=((0, "leave", 2),))
    g = GossipSpec(topology="ring", n_nodes=8,
                   elastic=ElasticSpec(churn=churn))
    eng = ElasticEngine(g)
    x = _x(8)
    st = eng.init_state({"x": x})
    c = wire.account_mix(wire.zero_counters(), g, eng, eng.backend,
                         st, "x", x, 1, 0)
    got = wire.unpack(c)
    # node 2 left: its two incident ring links are not scheduled-live; the
    # remaining 6 of 8 are realized (no faults configured)
    assert got.active_links == 6.0
    assert got.dropped_links == 0.0
    assert got.wire_bytes < got.raw_bytes  # wire scales by live fraction


# ---------------------------------------------------------------------------
# repro.comms.api: protocols + backend registry
# ---------------------------------------------------------------------------


def test_protocols_match_runtime_classes():
    from repro.comms import api
    assert isinstance(CommSpec(), api.CommLike)
    assert isinstance(ElasticSpec(), api.ElasticLike)
    assert isinstance(StackedBackend(), api.MixBackendProtocol)


def test_backend_registry_strings():
    from repro.comms import api
    from repro.comms.backend import make_backend, resolve_backend
    assert set(api.backend_names()) >= {"stacked", "shard_map"}
    assert isinstance(make_backend("stacked"), StackedBackend)
    with pytest.raises(ValueError, match="shard_map"):
        make_backend("shard_map")          # no mesh
    with pytest.raises(ValueError, match="registered"):
        make_backend("nope")
    # GossipSpec.backend accepts a registry name
    g = GossipSpec(topology="ring", n_nodes=4, backend="stacked")
    assert isinstance(resolve_backend(g), StackedBackend)
    tree = {"w": _x(4)}
    _assert_bit_equal(g.mix(tree, steps=1),
                      GossipSpec(topology="ring", n_nodes=4).mix(tree,
                                                                 steps=1))


# ---------------------------------------------------------------------------
# stiefel_mask deprecation + TrainSpec
# ---------------------------------------------------------------------------


def test_stiefel_mask_warns_once_and_maps_unchanged():
    import warnings

    from repro.core.minimax import MinimaxProblem, project_simplex
    from repro.geometry import base as gbase

    def loss(x, y, b):
        return jnp.sum(x["w"]) + jnp.sum(y)

    gbase._warned_stiefel_mask = False
    with pytest.warns(DeprecationWarning, match="stiefel_mask"):
        legacy = MinimaxProblem(loss_fn=loss, project_y=project_simplex,
                                stiefel_mask={"w": True, "b": False})
    with warnings.catch_warnings():
        warnings.simplefilter("error")     # second use must stay silent
        legacy2 = MinimaxProblem(loss_fn=loss, project_y=project_simplex,
                                 stiefel_mask={"w": True, "b": False})
        modern = MinimaxProblem(loss_fn=loss, project_y=project_simplex,
                                manifold_map={"w": "stiefel",
                                              "b": "euclidean"})
    for p in (legacy, legacy2):
        assert p.stiefel_mask == modern.stiefel_mask
        assert jax.tree.map(lambda m: m.name, p.manifold_map,
                            is_leaf=lambda s: isinstance(s, gbase.Manifold)) \
            == jax.tree.map(lambda m: m.name, modern.manifold_map,
                            is_leaf=lambda s: isinstance(s, gbase.Manifold))
    gbase._warned_stiefel_mask = False     # leave global state clean-ish


def test_fair_problem_uses_manifold_map_without_warning():
    import warnings

    from repro.objectives import fair
    params = fair.init_cnn(jax.random.PRNGKey(0))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        prob = fair.make_fair_problem(params)
    assert prob.stiefel_mask == fair.cnn_stiefel_mask(params)


def test_train_spec_equivalent_to_kwargs():
    from repro import configs
    from repro.launch.steps import TrainSpec, build_trainer
    cfg = configs.get_config("smollm-135m", smoke=True)
    opt_kw, _ = build_trainer(cfg, 2, optimizer="gt-gda", topology="full")
    opt_sp, _ = build_trainer(cfg, 2, TrainSpec(optimizer="gt-gda",
                                                topology="full"))
    assert type(opt_kw) is type(opt_sp)
    assert opt_kw.gossip.topology == opt_sp.gossip.topology == "full"
    assert opt_kw.gossip.comm == opt_sp.gossip.comm
    assert opt_sp.gossip.elastic is None
    # elastic threads through to the optimizer's engine
    es = ElasticSpec(churn=ChurnSchedule(kind="random", leave_rate=0.1))
    opt_el, _ = build_trainer(cfg, 2, TrainSpec(elastic=es))
    assert isinstance(opt_el.engine, ElasticEngine)
    assert opt_el.engine.elastic is es
