"""Serving subsystem tests: paged kernel, engine equivalence, scheduler,
replica gossip sync, tune registration, PRNG hygiene."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import ops, ref
from repro.kernels import paged_decode as pd
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.serve import (ContinuousBatchingScheduler, PagedKVSpec,
                         ReplicaGroup, Request, ServeEngine, serve_requests)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = configs.get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _paged_case(seed=0, s=5, hkv=2, g=1, hd=32, ps=8, m=6):
    rng = np.random.default_rng(seed)
    n_pages = s * m + 1
    q = jnp.asarray(rng.normal(size=(s, hkv * g, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, hd)), jnp.float32)
    seq = [1, 7, 13, 0, min(m * ps, 40)][:s]
    bt = np.full((s, m), -1, np.int32)
    nxt = 1
    for i, sl in enumerate(seq):
        for j in range(-(-sl // ps)):
            bt[i, j] = nxt
            nxt += 1
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(seq, jnp.int32)


# ---------------------------------------------------------------------------
# paged-decode kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g", [1, 2])
@pytest.mark.parametrize("window", [None, 5])
def test_paged_kernel_matches_oracle(g, window):
    q, kp, vp, bt, seq = _paged_case(g=g)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, seq, window=window)
    s, h, hd = q.shape
    hkv = kp.shape[2]
    got = pd.paged_decode_shgd(
        q.reshape(s, hkv, h // hkv, hd), kp, vp, bt, seq, window=window,
        interpret=True).reshape(s, h, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_paged_kernel_empty_slot_zeros():
    q, kp, vp, bt, seq = _paged_case()
    assert int(seq[3]) == 0
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, seq)
    got = ops.paged_decode_attention(q, kp, vp, bt, seq,
                                     impl="pallas_interpret")
    assert float(jnp.abs(got[3]).max()) == 0.0
    assert float(jnp.abs(want[3]).max()) == 0.0


def test_paged_dispatch_pads_ragged_table():
    # pages_per_block that doesn't divide M: ops pads the table with -1
    q, kp, vp, bt, seq = _paged_case(m=5)
    want = ops.paged_decode_attention(q, kp, vp, bt, seq, impl="ref")
    got = ops.paged_decode_attention(q, kp, vp, bt, seq,
                                     impl="pallas_interpret",
                                     pages_per_block=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# engine: paged decode == contiguous decode
# ---------------------------------------------------------------------------


def test_engine_matches_contiguous_greedy(smoke_model):
    cfg, params = smoke_model
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab_size))
    want = np.asarray(
        generate(cfg, params, jnp.asarray(prompt), 8, temperature=0.0))[0]
    spec = PagedKVSpec(page_size=4, n_pages=33, max_pages_per_slot=5)
    engine = ServeEngine(cfg, params, kv_spec=spec, n_slots=2,
                         temperature=0.0)
    sched = ContinuousBatchingScheduler(2, spec)
    fin = serve_requests(engine, sched,
                         [Request(prompt=prompt[0].tolist(),
                                  max_new_tokens=8)])
    assert fin[0].tokens == want.tolist()


def test_engine_greedy_matches_full_forward_argmax(smoke_model):
    # decode with the paged cache == argmax over a from-scratch full forward
    cfg, params = smoke_model
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (1, 9), 0, cfg.vocab_size))
    spec = PagedKVSpec(page_size=4, n_pages=33, max_pages_per_slot=5)
    engine = ServeEngine(cfg, params, kv_spec=spec, n_slots=1,
                         temperature=0.0)
    sched = ContinuousBatchingScheduler(1, spec)
    fin = serve_requests(engine, sched,
                         [Request(prompt=prompt[0].tolist(),
                                  max_new_tokens=6)])
    seq = prompt[0].tolist()
    for tok in fin[0].tokens:
        logits, _, _ = T.forward(params, cfg, jnp.asarray([seq]),
                                 mode="eval", last_logits_only=True)
        lg = np.asarray(logits[0, -1])
        top2 = np.sort(lg)[-2:]
        # only compare where argmax is numerically unambiguous
        if top2[1] - top2[0] > 1e-3:
            assert int(np.argmax(lg)) == tok
        seq.append(tok)


def test_engine_ragged_batch(smoke_model):
    # two ragged requests decoded together == each decoded alone
    cfg, params = smoke_model
    prompts = [np.asarray(jax.random.randint(
        jax.random.PRNGKey(10 + i), (n,), 0, cfg.vocab_size)).tolist()
        for i, n in enumerate((5, 14))]
    spec = PagedKVSpec(page_size=4, n_pages=33, max_pages_per_slot=6)

    def run(prompt_list, n_slots):
        engine = ServeEngine(cfg, params, kv_spec=spec, n_slots=n_slots,
                             temperature=0.0)
        sched = ContinuousBatchingScheduler(n_slots, spec)
        fin = serve_requests(engine, sched, [
            Request(prompt=p, max_new_tokens=7) for p in prompt_list])
        return {tuple(r.prompt): r.tokens for r in fin}

    together = run(prompts, 2)
    for p in prompts:
        alone = run([p], 1)
        assert together[tuple(p)] == alone[tuple(p)]


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def _spec(ps=4, n_pages=9, m=4):
    return PagedKVSpec(page_size=ps, n_pages=n_pages, max_pages_per_slot=m)


def test_scheduler_admit_evict_refill():
    spec = _spec()                       # 8 usable pages, 2 pages/request
    sched = ContinuousBatchingScheduler(2, spec)
    reqs = [Request(prompt=[1] * 4, max_new_tokens=4, arrival=0.0)
            for _ in range(4)]
    for r in reqs:
        sched.submit(r)
    adm = sched.admit(now=0.0)
    assert [s for s, _ in adm] == [0, 1]
    assert sched.pool.n_free == 4
    # slot 0 finishes its budget -> evicted, pages released, refilled
    for i in range(4):
        done = sched.on_token(0, 7, now=0.1 + i * 0.01)
    assert done is reqs[0] and done.latency > 0
    assert sched.pool.n_free == 6
    adm = sched.admit(now=0.2)
    assert [s for s, _ in adm] == [0] and adm[0][1] is reqs[2]
    # EOS eviction
    sched.slots[1].request.eos_id = 9
    assert sched.on_token(1, 9, now=0.3) is reqs[1]


def test_scheduler_respects_arrivals_and_pages():
    spec = _spec(n_pages=5)              # only 4 usable pages
    sched = ContinuousBatchingScheduler(2, spec)
    sched.submit(Request(prompt=[1] * 8, max_new_tokens=8, arrival=0.0))  # 4p
    sched.submit(Request(prompt=[1] * 4, max_new_tokens=4, arrival=5.0))
    adm = sched.admit(now=0.0)
    assert len(adm) == 1 and sched.pool.n_free == 0
    # head-of-queue hasn't arrived yet -> nothing admitted even at now=1
    assert sched.admit(now=1.0) == []
    for i in range(8):
        sched.on_token(0, 3, now=2.0 + i * 0.1)
    assert sched.admit(now=4.0) == []    # arrival still in the future
    assert len(sched.admit(now=5.0)) == 1


def test_scheduler_static_mode_drains_before_refill():
    spec = _spec(n_pages=17)
    sched = ContinuousBatchingScheduler(2, spec, refill="static")
    for _ in range(3):
        sched.submit(Request(prompt=[1] * 4, max_new_tokens=2, arrival=0.0))
    assert len(sched.admit(now=0.0)) == 2
    sched.on_token(0, 1, 0.1)
    done = sched.on_token(0, 1, 0.2)
    assert done is not None
    assert sched.admit(now=0.3) == []    # slot 1 still running
    sched.on_token(1, 1, 0.4)
    sched.on_token(1, 1, 0.5)
    assert len(sched.admit(now=0.6)) == 1


def test_scheduler_rejects_oversized_request():
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(1, _spec()).submit(
            Request(prompt=[1] * 20, max_new_tokens=20))


# ---------------------------------------------------------------------------
# replica gossip sync
# ---------------------------------------------------------------------------


def test_replica_sync_reduces_drift_monotonically(smoke_model):
    cfg, params = smoke_model
    group = ReplicaGroup(params, 2, seed=0)
    assert group.drift() == 0.0
    d0 = group.perturb(0.02)
    assert d0 > 0.01
    trace = group.sync(rounds=4)
    assert all(b <= a * (1 + 1e-6) for a, b in zip(trace, trace[1:]))
    assert trace[-1] < 0.2 * d0
    wire = group.wire_stats()
    assert wire["rounds"] == 4
    assert wire["wire_bytes"] < 0.5 * wire["raw_bytes"]   # int8 on the wire


def test_replica_params_usable_by_engine(smoke_model):
    cfg, params = smoke_model
    group = ReplicaGroup(params, 2, seed=0)
    group.perturb(0.01)
    spec = PagedKVSpec(page_size=4, n_pages=17, max_pages_per_slot=4)
    engine = ServeEngine(cfg, group.replica(0), kv_spec=spec, n_slots=1,
                         temperature=0.0)
    sched = ContinuousBatchingScheduler(1, spec)
    fin = serve_requests(engine, sched,
                         [Request(prompt=[1, 2, 3], max_new_tokens=3)])
    assert len(fin[0].tokens) == 3


# ---------------------------------------------------------------------------
# tune registration (flash_attention + paged_decode)
# ---------------------------------------------------------------------------


def test_tune_search_covers_attention_kernels(tmp_path, monkeypatch):
    from repro.kernels import tune
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_TUNE", "search")
    e1 = tune.autotune("flash_attention", (1, 32, 32, 2, 16), "float32")
    e2 = tune.autotune("paged_decode", (2, 4, 8, 16), "float32")
    for e in (e1, e2):
        # every candidate oracle-gated, default included
        assert all("accurate" in c for c in e["candidates"])
        assert all(c["accurate"] for c in e["candidates"])
    assert tune.lookup("flash_attention", (1, 32, 32, 2, 16),
                       "float32") == e1["config"]
    assert tune.lookup("paged_decode", (2, 4, 8, 16),
                       "float32") == e2["config"]


# ---------------------------------------------------------------------------
# PRNG hygiene in the legacy generate loop
# ---------------------------------------------------------------------------


def test_generate_sampling_keys_are_distinct(smoke_model, monkeypatch):
    cfg, params = smoke_model
    seen = []
    orig = jax.random.categorical

    def spy(key, *a, **kw):
        seen.append(np.asarray(jax.random.key_data(key)).tolist())
        return orig(key, *a, **kw)

    monkeypatch.setattr(jax.random, "categorical", spy)
    prompt = jnp.asarray(np.zeros((1, 4), np.int32))
    generate(cfg, params, prompt, 4, temperature=1.0)
    assert len(seen) == 4
    assert len({tuple(k) for k in seen}) == 4   # no key reuse
