"""Comms subsystem tests: compression + error feedback, channel, kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import (ChannelModel, CommEngine, CommSpec, Int8Stochastic,
                         LowRank, TopK, make_compressor, tree_bits)
from repro.core import gossip as G

N = 12


def _spec(comm=None, n=N):
    return G.GossipSpec(topology="ring", n_nodes=n, k_steps=1, comm=comm)


def _tree(n=N, seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (n, 32, 4)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 128))}


def _cons_err(tree):
    return float(sum(jnp.sum((l - jnp.mean(l, 0, keepdims=True)) ** 2)
                     for l in jax.tree.leaves(tree)))


def _run_gossip(comm, rounds, tree0):
    eng = CommEngine(_spec(comm))
    step = jax.jit(lambda x, cs, t: eng.mix(cs, "x", x, steps=1, rnd=t))
    x, cs = tree0, eng.init_state({"x": tree0})
    for t in range(rounds):
        x, cs = step(x, cs, t)
    return _cons_err(x)


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------


def test_ef_int8_gossip_converges_naive_plateaus():
    """CHOCO memory keeps compressed gossip contracting; without it the
    iterates stall at the quantizer's noise floor."""
    tree0 = _tree()
    err0 = _cons_err(tree0)
    ef = _run_gossip(CommSpec(compressor="int8", gamma=0.95), 200, tree0)
    naive = _run_gossip(CommSpec(compressor="int8", gamma=0.95,
                                 error_feedback=False), 200, tree0)
    assert ef < 1e-4 * err0          # error -> 0
    assert ef < 0.05 * naive         # EF decisively beats naive


@pytest.mark.parametrize("comm", [
    CommSpec(compressor="topk", topk_frac=0.2, gamma=0.4),
    CommSpec(compressor="lowrank", rank=2, gamma=0.2),
])
def test_ef_sparse_lowrank_gossip_contracts(comm):
    tree0 = _tree()
    err = _run_gossip(comm, 120, tree0)
    assert err < 0.2 * _cons_err(tree0)


def test_identity_comm_matches_exact_gossip():
    """Identity compressor + gamma=1 reduces the CHOCO round to W^s x."""
    tree0 = _tree()
    # identity compressor alone is disabled; force an engine via a channel
    # knob that keeps hops exact (round_robin would change W_t, so compare
    # through the compressed path with int8 replaced by identity).
    eng = CommEngine(_spec(CommSpec(compressor="topk", topk_frac=1.0,
                                    gamma=1.0)))
    cs = eng.init_state({"x": tree0})
    got, _ = eng.mix(cs, "x", tree0, steps=2, rnd=0)
    want = _spec().mix(tree0, steps=2)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# adaptive gamma
# ---------------------------------------------------------------------------


def test_adaptive_gamma_within_hand_tuned_consensus_error():
    """gamma derived from the tracked contraction delta converges at least
    as well (up to small-constant slack) as the hand-tuned constants."""
    tree0 = _tree()
    err0 = _cons_err(tree0)
    fixed = _run_gossip(CommSpec(compressor="int8", gamma=0.95), 200, tree0)
    adapt = _run_gossip(CommSpec(compressor="int8", gamma_mode="adaptive"),
                        200, tree0)
    assert adapt < 1e-4 * err0                      # still contracts to zero
    assert adapt <= 5.0 * max(fixed, 1e-14)         # within the tuned constant
    # aggressive sparsifier: the tracked delta (~0.65) beats the conservative
    # hand constant 0.4 outright
    fixed_tk = _run_gossip(CommSpec(compressor="topk", topk_frac=0.2,
                                    gamma=0.4), 120, tree0)
    adapt_tk = _run_gossip(CommSpec(compressor="topk", topk_frac=0.2,
                                    gamma_mode="adaptive"), 120, tree0)
    assert adapt_tk <= 5.0 * max(fixed_tk, 1e-14)


def test_adaptive_gamma_tracks_compressor_delta():
    """CommState.deltas is an EMA of 1 - ||C(r)-r||^2/||r||^2: near 1 for
    int8, materially below 1 for a 20% sparsifier, untracked when fixed."""
    tree0 = _tree()

    def run(comm, rounds=30):
        eng = CommEngine(_spec(comm))
        step = jax.jit(lambda x, cs, t: eng.mix(cs, "x", x, steps=1, rnd=t))
        x, cs = tree0, eng.init_state({"x": tree0})
        for t in range(rounds):
            x, cs = step(x, cs, t)
        return cs

    cs = run(CommSpec(compressor="int8", gamma_mode="adaptive"))
    d_int8 = float(cs.deltas["x"])
    assert 0.99 <= d_int8 <= 1.0
    cs = run(CommSpec(compressor="topk", topk_frac=0.2,
                      gamma_mode="adaptive"))
    d_topk = float(cs.deltas["x"])
    assert 0.3 <= d_topk <= 0.9 and d_topk < d_int8
    cs = run(CommSpec(compressor="int8", gamma=0.9))
    assert cs.deltas is None


# ---------------------------------------------------------------------------
# channel
# ---------------------------------------------------------------------------


def test_channel_droprate0_bitexact_mix_ring():
    tree0 = _tree()
    ch = ChannelModel.for_gossip(_spec(), CommSpec())
    out = ch.mix_hop(tree0, 0, jax.random.PRNGKey(0))
    want = G.mix_ring(tree0, steps=1)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        assert bool(jnp.all(a == b))  # bit-exact: same op, same order


@pytest.mark.parametrize("comm", [
    CommSpec(drop_rate=0.3),
    CommSpec(straggler_rate=0.25),
    CommSpec(schedule="round_robin"),
    CommSpec(schedule="matching"),
    CommSpec(drop_rate=0.1, straggler_rate=0.1, schedule="matching"),
])
def test_channel_wt_doubly_stochastic(comm):
    ch = ChannelModel.for_gossip(_spec(), comm)
    for rnd in range(4):
        wt = np.asarray(ch.w_t(rnd, jax.random.PRNGKey(rnd)))
        np.testing.assert_allclose(wt.sum(0), 1.0, atol=1e-6)
        np.testing.assert_allclose(wt.sum(1), 1.0, atol=1e-6)
        np.testing.assert_allclose(wt, wt.T, atol=1e-6)
        assert (wt >= -1e-6).all()


def test_channel_matchings_are_matchings():
    ch = ChannelModel.for_gossip(_spec(), CommSpec(schedule="matching"))
    masks = np.asarray(ch._subset_masks)
    assert masks.shape[0] >= 2          # even ring splits into >= 2 classes
    # the classes exactly cover the base edge set
    edges = (np.asarray(_spec().matrix) > 0) & ~np.eye(N, dtype=bool)
    np.testing.assert_allclose(masks.sum(0), edges.astype(np.float32))
    for m in masks:                      # each class touches a node <= once
        assert (m.sum(1) <= 1.0 + 1e-9).all()


def test_faulty_channel_still_reaches_consensus():
    tree0 = _tree()
    comm = CommSpec(drop_rate=0.2, schedule="round_robin")
    eng = CommEngine(_spec(comm))
    step = jax.jit(lambda x, cs, t: eng.mix(cs, "x", x, steps=1, rnd=t))
    x, cs = tree0, eng.init_state({"x": tree0})
    for t in range(250):
        x, cs = step(x, cs, t)
    assert _cons_err(x) < 1e-3 * _cons_err(tree0)
    # mean preserved: every W_t is doubly stochastic
    for a, b in zip(jax.tree.leaves(x), jax.tree.leaves(tree0)):
        np.testing.assert_allclose(jnp.mean(a, 0), jnp.mean(b, 0), atol=1e-4)


# ---------------------------------------------------------------------------
# quant_mix kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rows,shape", [(8, (1024,)), (20, (37, 13)),
                                        (6, (257,)), (3, (8, 128))])
def test_quant_mix_interpret_matches_ref(rows, shape):
    from repro.kernels import ops
    key = jax.random.PRNGKey(rows)
    qs = [jax.random.randint(jax.random.fold_in(key, i), (rows, *shape),
                             -127, 128, jnp.int8) for i in range(3)]
    ss = [0.02 * jax.random.uniform(jax.random.fold_in(key, 10 + i),
                                    (rows, 1)) + 1e-4 for i in range(3)]
    want = ops.quant_mix(*qs, *ss, w_self=1 / 3, w_side=1 / 3, impl="ref")
    got = ops.quant_mix(*qs, *ss, w_self=1 / 3, w_side=1 / 3,
                        impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


def test_fused_int8_hop_matches_unfused():
    """Engine output with the fused quant_mix hop == plain dense-hat path."""
    tree0 = _tree()
    outs = []
    for fuse in (True, False):
        comm = CommSpec(compressor="int8", gamma=0.9, fuse_kernel=fuse)
        eng = CommEngine(_spec(comm))
        cs = eng.init_state({"x": tree0})
        out, _ = eng.mix(cs, "x", tree0, steps=2, rnd=3)
        outs.append(out)
    for a, b in zip(jax.tree.leaves(outs[0]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# compressors & accounting
# ---------------------------------------------------------------------------


def test_int8_quantization_roundtrip_error_bounded():
    comp = Int8Stochastic()
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64))
    rec = comp(jax.random.PRNGKey(1), x)
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 127.0
    assert float(jnp.max(jnp.abs(rec - x) / scale)) <= 1.0 + 1e-5


def test_topk_keeps_largest():
    comp = TopK(frac=0.25)
    x = jnp.asarray([[4.0, -5.0, 1.0, 0.5, 3.0, -2.0, 0.1, 0.2]])
    rec = np.asarray(comp(jax.random.PRNGKey(0), x))
    np.testing.assert_allclose(rec, [[4.0, -5.0, 0, 0, 0, 0, 0, 0]])


def test_lowrank_is_projection():
    comp = LowRank(rank=2)
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 6))
    rec = comp(jax.random.PRNGKey(1), x)
    # projection: reconstruction never exceeds the input's norm, and
    # applying the sketch direction again is idempotent in spirit
    assert float(jnp.linalg.norm(rec)) <= float(jnp.linalg.norm(x)) + 1e-5
    # 2-D (non-matrix) leaves pass through untouched
    flat = jax.random.normal(jax.random.PRNGKey(2), (3, 50))
    np.testing.assert_allclose(comp(jax.random.PRNGKey(3), flat), flat)


def test_bits_accounting():
    tree = {"a": jnp.zeros((10, 100)), "b": jnp.zeros((10, 16, 8))}
    n_params = 10 * 100 + 10 * 16 * 8
    assert tree_bits(make_compressor(CommSpec(compressor="none")), tree) \
        == 32 * n_params
    int8 = tree_bits(make_compressor(CommSpec(compressor="int8")), tree)
    assert int8 == 8 * n_params + 2 * 10 * 32
    topk = tree_bits(make_compressor(
        CommSpec(compressor="topk", topk_frac=0.1)), tree)
    assert topk == 10 * (10 + 13) * 64   # ceil-ish rounding of k per leaf
    lowrank = tree_bits(make_compressor(
        CommSpec(compressor="lowrank", rank=2)), tree)
    assert lowrank == 32 * 10 * 100 + 10 * 2 * (16 + 8) * 32


# ---------------------------------------------------------------------------
# optimizer integration
# ---------------------------------------------------------------------------


def _toy_problem():
    from repro.core.minimax import MinimaxProblem
    from repro.core import manifolds as M

    d, r, ngrp = 8, 2, 3

    def loss_fn(x, y, batch):
        z = batch["z"]                      # (b, d)
        proj = z @ x["w"]                   # (b, r)
        per_group = jnp.stack([jnp.mean(proj ** 2)] * ngrp) + x["bias"].sum()
        return jnp.sum(y * per_group) - 0.5 * jnp.sum(y ** 2)

    x0 = {"w": M.random_stiefel(jax.random.PRNGKey(0), d, r),
          "bias": jnp.zeros((4,))}
    mmap = {"w": "stiefel", "bias": "euclidean"}
    return MinimaxProblem(
        loss_fn=loss_fn, manifold_map=mmap,
        project_y=lambda y: jnp.clip(y, 0.0, 1.0)), x0, ngrp


@pytest.mark.parametrize("name", ["drgda", "gt-gda", "dm-hsgd", "gt-srvr"])
def test_optimizers_run_with_comm(name):
    """Every optimizer threads CommState through its jitted step."""
    from repro.core import OPTIMIZERS
    from repro.core.gda import broadcast_to_nodes

    problem, x0, ngrp = _toy_problem()
    n = 4
    comm = CommSpec(compressor="int8", gamma=0.9, drop_rate=0.1)
    opt = OPTIMIZERS[name](problem, _spec(comm, n=n))
    xs = broadcast_to_nodes(x0, n)
    ys = jnp.full((n, ngrp), 1.0 / ngrp)
    batch = {"z": jax.random.normal(jax.random.PRNGKey(1), (n, 16, 8))}
    state = opt.init(xs, ys, batch)
    assert state.comm is not None
    fns = opt.make_step(donate=False)
    step_fn = fns[0] if isinstance(fns, tuple) else fns
    for t in range(3):
        state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics.loss))


def test_drgda_identity_comm_matches_exact():
    """A channel-only comm spec with zero faults must not change DRGDA."""
    from repro.core import OPTIMIZERS
    from repro.core.gda import broadcast_to_nodes

    problem, x0, ngrp = _toy_problem()
    n = 4
    xs = broadcast_to_nodes(x0, n)
    ys = jnp.full((n, ngrp), 1.0 / ngrp)
    batch = {"z": jax.random.normal(jax.random.PRNGKey(1), (n, 16, 8))}

    states = []
    for comm in (None, CommSpec(compressor="topk", topk_frac=1.0, gamma=1.0)):
        opt = OPTIMIZERS["drgda"](problem, _spec(comm, n=n))
        state = opt.init(xs, ys, batch)
        step_fn = opt.make_step(donate=False)
        for _ in range(2):
            state, _ = step_fn(state, batch)
        states.append(state)
    for a, b in zip(jax.tree.leaves(states[0].x), jax.tree.leaves(states[1].x)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_config_comm_knobs_roundtrip():
    from repro.configs.base import ModelConfig

    assert ModelConfig().comm_spec() is None
    cfg = ModelConfig(comm_compressor="int8", comm_drop_rate=0.1)
    spec = cfg.comm_spec()
    assert spec is not None and spec.enabled and spec.compressor == "int8"
    assert spec.drop_rate == 0.1
