"""repro.geometry: retraction axioms for every registered geometry x
retraction, the fused Pallas retraction vs the eigh oracle, the ManifoldMap
back-compat shim, Product-manifold ops, and the Grassmann robust-PCA
workload."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import geometry as G
from repro.core import manifolds as M
from repro.kernels import ops

SET = dict(deadline=None, max_examples=12)

# (geometry, retraction) pairs under the axiom suite; polar_fused is
# exercised separately (it takes ambient directions and needs the ops
# dispatch), Euclidean is trivially exact for every axiom.
CASES = [(name, kind)
         for name, m in sorted(G.REGISTRY.items())
         for kind in m.retractions if kind != "polar_fused"]


@st.composite
def dims(draw):
    d = draw(st.integers(3, 48))
    r = draw(st.integers(1, min(d, 12)))
    seed = draw(st.integers(0, 2 ** 16))
    return d, r, seed


def _point_and_tangent(m: G.Manifold, d, r, seed, scale=0.2):
    x = m.rand(jax.random.PRNGKey(seed), d, r)
    g = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, r))
    u = m.tangent_project(x, g)
    nrm = jnp.maximum(jnp.linalg.norm(u), 1e-9)
    return x, scale * u / nrm


# ---------------------------------------------------------------------------
# retraction axioms: every geometry x retraction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,kind", CASES)
def test_retraction_axioms(name, kind):
    m = G.get(name)

    @given(dims())
    @settings(**SET)
    def run(drs):
        d, r, seed = drs
        x, u = _point_and_tangent(m, d, r, seed)
        # R_x(0) = x
        np.testing.assert_allclose(m.retract(x, jnp.zeros_like(x), kind), x,
                                   atol=1e-5)
        # result on-manifold
        y = m.retract(x, u, kind)
        assert float(jnp.max(m.check(y))) < 1e-5
        # first-order agreement R_x(tu) = x + tu + O(t^2): generous
        # second-order constant shared by all kinds here
        for t in (0.5, 0.25):
            resid = float(jnp.linalg.norm(m.retract(x, t * u, kind) - (x + t * u)))
            unorm2 = float(jnp.sum((t * u) ** 2))
            assert resid <= 8.0 * unorm2 + 1e-5, (d, r, seed, t)

    run()


@pytest.mark.parametrize("name", sorted(G.REGISTRY))
def test_tangent_projection_idempotent_and_kills_base(name):
    m = G.get(name)

    @given(dims())
    @settings(**SET)
    def run(drs):
        d, r, seed = drs
        x = m.rand(jax.random.PRNGKey(seed), d, r)
        g = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, r))
        u = m.tangent_project(x, g)
        np.testing.assert_allclose(m.tangent_project(x, u), u, atol=1e-5)
        if name != "euclidean":   # flat space has no vertical component
            np.testing.assert_allclose(m.tangent_project(x, x), 0.0, atol=1e-5)
        # rand lands on-manifold; project is idempotent
        assert float(jnp.max(m.check(x))) < 1e-4
        np.testing.assert_allclose(m.project(x), x, atol=1e-4)

    run()


@pytest.mark.parametrize("name", sorted(G.REGISTRY))
def test_consensus_mean_and_dist(name):
    m = G.get(name)
    x = m.rand(jax.random.PRNGKey(3), 24, 6)
    same = jnp.broadcast_to(x[None], (5, 24, 6))
    xhat = m.consensus_mean(same)
    assert float(jnp.max(m.check(xhat))) < 1e-4
    if name != "grassmann":   # a Grassmann mean is any representative basis
        np.testing.assert_allclose(xhat, x, atol=1e-4)
    assert float(m.dist(xhat, x)) < 1e-2
    # perturbed cloud: mean is on-manifold and close to the cloud
    pert = x[None] + 0.01 * jax.random.normal(jax.random.PRNGKey(4), (8, 24, 6))
    xs = jax.vmap(m.project)(pert)
    xhat = m.consensus_mean(xs)
    assert float(jnp.max(m.check(xhat))) < 1e-4
    assert float(m.dist(xhat, x)) < 0.1


def test_cayley_any_step_size_stays_feasible():
    """The CG normal-equation solve converges for ANY ||u|| (the Neumann
    fixed point needs ||u|| < 1 and documents so)."""
    x = M.random_stiefel(jax.random.PRNGKey(0), 32, 8)
    g = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
    u = M.tangent_project(x, g)
    u = u / jnp.linalg.norm(u)
    for scale in (0.1, 1.0, 4.0):
        y = M.retract_cayley(x, scale * u)
        assert float(M.stiefel_error(y)) < 1e-4, scale
    # neumann agrees on small steps
    us = 0.05 * u / jnp.linalg.norm(u)
    np.testing.assert_allclose(M.retract_cayley(x, us, solver="neumann"),
                               M.retract_cayley(x, us), atol=1e-5)


def test_unknown_retraction_name_rejected_by_optimizer():
    """Per-leaf resolution falls back silently, so DecentralizedGDA must
    reject globally-unknown names (typo guard)."""
    from repro.core import DRGDA, GDAHyper, GossipSpec
    from repro.core.minimax import MinimaxProblem

    prob = MinimaxProblem(loss_fn=lambda x, y, b: jnp.sum(x["w"]),
                          project_y=lambda y: y, stiefel_mask={"w": True})
    spec = GossipSpec(topology="ring", n_nodes=4)
    with pytest.raises(ValueError, match="unknown retraction"):
        DRGDA(prob, spec, GDAHyper(retraction="polr"))
    for ok in ("polar", "qr", "cayley", "polar_fused", "normalize", "add"):
        DRGDA(prob, spec, GDAHyper(retraction=ok))


def test_manifold_map_from_paths_tall_filter_is_per_geometry():
    """d >= r is a Stiefel/Grassmann requirement; norm-constraint
    geometries must constrain wide leaves too."""
    params = {"wide": jnp.zeros((4, 16)), "tall": jnp.zeros((16, 4)),
              "vec": jnp.zeros((8,))}
    st = G.manifold_map_from_paths(params, lambda p: True, "stiefel")
    assert st["wide"] is G.EUCLIDEAN and st["tall"] is G.STIEFEL
    ob = G.manifold_map_from_paths(params, lambda p: True, "oblique")
    assert ob["wide"] is G.OBLIQUE and ob["tall"] is G.OBLIQUE
    assert ob["vec"] is G.EUCLIDEAN


def test_registry_dispatch_and_unknown_kind():
    assert G.get("stiefel") is G.STIEFEL
    with pytest.raises(ValueError):
        G.get("klein-bottle")
    x = M.random_stiefel(jax.random.PRNGKey(0), 8, 2)
    with pytest.raises(ValueError):
        M.retract(x, jnp.zeros_like(x), "bogus")
    # resolve_retraction falls back to each geometry's default
    assert G.get("oblique").resolve_retraction("cayley") == "normalize"
    assert G.get("euclidean").resolve_retraction("polar_fused") == "add"


# ---------------------------------------------------------------------------
# fused Pallas retraction vs the eigh oracle
# ---------------------------------------------------------------------------


FUSED_CASES = [(16, 4), (64, 16), (100, 7), (200, 9), (256, 128)]


@pytest.mark.parametrize("d,r", FUSED_CASES)
@pytest.mark.parametrize("impl", ["ref", "pallas_interpret"])
def test_fused_retract_matches_eigh_oracle(d, r, impl):
    x = M.random_stiefel(jax.random.PRNGKey(d + r), d, r)
    g = 0.3 * jax.random.normal(jax.random.PRNGKey(d + r + 1), (d, r))
    want = M.retract_polar(x, M.tangent_project(x, g), method="eigh")
    got = ops.fused_retract(x, g, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)
    assert float(M.stiefel_error(got)) < 1e-4


def test_fused_retract_node_stacked_batch():
    x = M.random_stiefel(jax.random.PRNGKey(0), 48, 8, batch=(6,))
    g = 0.2 * jax.random.normal(jax.random.PRNGKey(1), (6, 48, 8))
    want = M.retract_polar(x, M.tangent_project(x, g), method="eigh")
    got = ops.fused_retract(x, g, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-5)


def test_polar_fused_hyper_runs_drgda():
    """GDAHyper(retraction="polar_fused") must produce (ref-dispatch) steps
    equivalent to the unfused polar path within NS/fp32 tolerance."""
    from repro.core import DRGDA, GDAHyper, GossipSpec
    from repro.core.gda import broadcast_to_nodes
    from repro.core.minimax import MinimaxProblem, project_simplex

    d, r, grp, n = 12, 3, 3, 6
    a = jnp.asarray(np.random.RandomState(0).randn(grp, d, d), jnp.float32)
    a = (a + jnp.swapaxes(a, 1, 2)) / 2

    def loss_fn(x, y, batch):
        lg = -jnp.einsum("dr,gde,er->g", x["w"], a + batch, x["w"])
        return jnp.dot(y, lg) - jnp.sum((y - 1.0 / grp) ** 2)

    prob = MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          stiefel_mask={"w": True})
    x0 = broadcast_to_nodes({"w": M.random_stiefel(jax.random.PRNGKey(5), d, r)}, n)
    y0 = jnp.full((n, grp), 1.0 / grp)
    batches = 0.05 * jax.random.normal(jax.random.PRNGKey(6), (n, grp, d, d))

    finals = []
    for kind in ("polar", "polar_fused"):
        opt = DRGDA(prob, GossipSpec(topology="ring", n_nodes=n),
                    GDAHyper(alpha=0.5, beta=0.05, eta=0.2, retraction=kind))
        state = opt.init(x0, y0, batches)
        step = opt.make_step(donate=False)
        for _ in range(25):
            state, _ = step(state, batches)
        assert float(M.stiefel_error(state.x["w"]).max()) < 1e-4
        finals.append(state.x["w"])
    np.testing.assert_allclose(np.asarray(finals[0]), np.asarray(finals[1]),
                               atol=2e-4)


# ---------------------------------------------------------------------------
# ManifoldMap: legacy bool masks, strings, instances; Product manifold
# ---------------------------------------------------------------------------


def test_manifold_map_accepts_legacy_bool_mask():
    from repro.core.minimax import MinimaxProblem

    prob = MinimaxProblem(loss_fn=lambda x, y, b: jnp.sum(x["w"]) + jnp.sum(y),
                          project_y=lambda y: y,
                          stiefel_mask={"w": True, "bias": False})
    assert prob.manifold_map["w"] is G.STIEFEL
    assert prob.manifold_map["bias"] is G.EUCLIDEAN
    assert prob.stiefel_mask == {"w": True, "bias": False}


def test_manifold_map_strings_and_instances_normalize():
    mmap = G.as_manifold_map({"a": "grassmann", "b": G.OBLIQUE, "c": False})
    assert mmap["a"] is G.GRASSMANN
    assert mmap["b"] is G.OBLIQUE
    assert mmap["c"] is G.EUCLIDEAN
    assert G.bool_mask(mmap) == {"a": False, "b": False, "c": False}


def test_rgrads_match_legacy_stiefel_path():
    """The geometry-generic rgrads must equal the historical masked path."""
    from repro.core.minimax import MinimaxProblem, apply_masked

    def loss_fn(x, y, b):
        return jnp.sum(x["w"] * b) + jnp.sum(x["e"] ** 2) + jnp.sum(y)

    prob = MinimaxProblem(loss_fn=loss_fn, project_y=lambda y: y,
                          stiefel_mask={"w": True, "e": False})
    x = {"w": M.random_stiefel(jax.random.PRNGKey(0), 10, 3),
         "e": jnp.ones((4, 2))}
    batch = jnp.ones((10, 3))
    rgx, _ = prob.rgrads(x, jnp.zeros((3,)), batch)
    gx, _ = prob.grads(x, jnp.zeros((3,)), batch)
    want = apply_masked({"w": True, "e": False}, x, gx,
                        stiefel_fn=M.tangent_project, eucl_fn=lambda _, g: g)
    for k in x:
        np.testing.assert_array_equal(np.asarray(rgx[k]), np.asarray(want[k]))


def test_product_manifold_ops():
    pm = G.Product({"w": "stiefel", "s": "sphere", "e": "euclidean"})
    key = jax.random.PRNGKey(0)
    like = {"w": jnp.zeros((16, 4)), "s": jnp.zeros((6, 2)),
            "e": jnp.zeros((3, 3))}
    x = pm.rand(key, like)
    assert float(pm.check(x)) < 1e-4
    g = jax.tree.map(lambda l: jnp.ones_like(l), like)
    u = pm.tangent_project(x, g)
    y = pm.retract(x, jax.tree.map(lambda t: 0.1 * t, u), kind="qr")
    assert float(pm.check(y)) < 1e-4
    assert float(pm.dist(x, x)) < 1e-2
    # feasible_init respects every leaf's geometry
    raw = jax.tree.map(lambda l: l + 3.0, g)
    init = pm.feasible_init(raw)
    assert float(pm.check(init)) < 1e-4
    np.testing.assert_array_equal(np.asarray(init["e"]), np.asarray(raw["e"]))


def test_validate_manifold_generalizes_validate_stiefel():
    from repro.core.minimax import validate_manifold, validate_stiefel

    x = {"w": M.random_stiefel(jax.random.PRNGKey(0), 12, 4),
         "e": jnp.full((3, 3), 7.0)}
    assert float(validate_stiefel(x, {"w": True, "e": False})) < 1e-5
    assert float(validate_manifold(x, {"w": "stiefel", "e": "euclidean"})) < 1e-5
    bad = {"w": x["w"] * 2.0, "e": x["e"]}
    assert float(validate_manifold(bad, {"w": "stiefel", "e": "euclidean"})) > 0.1
    ob = {"w": G.OBLIQUE.rand(jax.random.PRNGKey(1), 9, 5), "e": x["e"]}
    assert float(validate_manifold(ob, {"w": "oblique", "e": False})) < 1e-5


# ---------------------------------------------------------------------------
# Grassmann robust PCA: the new workload end to end (short run)
# ---------------------------------------------------------------------------


def test_robust_pca_drgda_converges_and_beats_pca_worst_case():
    from repro.core import DRGDA, GDAHyper, GossipSpec
    from repro.core.gda import broadcast_to_nodes
    from repro.core.metric import convergence_metric
    from repro.objectives import robust_pca as rp

    d, r, m, n, rho = 16, 2, 16, 6, 0.5
    problem = rp.make_robust_pca_problem(rho=rho)
    batches, basis = rp.make_batches(jax.random.PRNGKey(1), n, m, d, r,
                                     outlier_frac=0.1, outlier_scale=1.5)
    x0 = broadcast_to_nodes(
        {"w": G.GRASSMANN.rand(jax.random.PRNGKey(0), d, r)}, n)
    opt = DRGDA(problem, GossipSpec(topology="ring", n_nodes=n),
                GDAHyper(alpha=0.5, beta=0.1, eta=0.3))
    state = opt.init(x0, rp.init_y(n, m), batches)
    step = opt.make_step(donate=False)
    met0 = convergence_metric(problem, state.x, state.y, batches)
    for _ in range(400):
        state, _ = step(state, batches)
    met = convergence_metric(problem, state.x, state.y, batches)
    assert float(met["M_t"]) < 0.05 * float(met0["M_t"])
    assert float(met["stiefel_residual"]) < 1e-4       # representative on St
    assert float(G.GRASSMANN.dist(state.x["w"][0], basis)) < 0.6

    def phi(x):
        ystar = rp.robust_pca_y_star({"w": x}, batches, rho=rho)
        res = jnp.mean(jax.vmap(lambda z: rp.residuals(x, z))(batches["z"]), 0)
        return float(jnp.dot(ystar, res) - rho * jnp.sum((ystar - 1 / m) ** 2))

    z = np.asarray(batches["z"].reshape(-1, d))
    pca = jnp.asarray(np.linalg.eigh(z.T @ z)[1][:, -r:])
    assert phi(state.x["w"][0]) <= phi(pca) + 1e-4


def test_robust_pca_objective_is_basis_invariant():
    """A Grassmann objective: rotating the basis within the span must not
    change the loss (what the quotient geometry buys)."""
    from repro.objectives import robust_pca as rp

    d, r, m = 12, 3, 10
    x = G.GRASSMANN.rand(jax.random.PRNGKey(0), d, r)
    q = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (r, r)))[0]
    z = jax.random.normal(jax.random.PRNGKey(2), (m, d))
    y = jnp.full((m,), 1.0 / m)
    l1 = rp.robust_pca_loss({"w": x}, y, {"z": z}, rho=0.5)
    l2 = rp.robust_pca_loss({"w": x @ q}, y, {"z": z}, rho=0.5)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5)
