"""§Perf knob correctness: every optimization must be semantics-preserving
(the hillclimb changes implementations, never Algorithm 1/2)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ModelConfig, MoESpec
from repro.models import moe, transformer as T
from repro.objectives import lm


def test_grouped_dispatch_exact_at_high_capacity():
    cfg = ModelConfig(d_model=32, d_ff=64)
    spec = MoESpec(n_experts=8, top_k=2, d_expert=64, n_shared=1,
                   capacity_factor=4.0)
    params = moe.init_moe(jax.random.PRNGKey(0), cfg, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    y_ref, _ = moe.apply_moe(params, x, spec)
    for g in (2, 4, -1):
        y_g, _ = moe.apply_moe(
            params, x, dataclasses.replace(spec, dispatch_groups=g))
        np.testing.assert_allclose(y_g, y_ref, atol=1e-5), g


def test_ce_impls_match_values_and_grads():
    lg = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 33))
    tg = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 33)

    def loss(l, impl):
        return lm.token_ce(l, tg, impl).sum()

    v1, g1 = jax.value_and_grad(loss)(lg, "gather")
    v2, g2 = jax.value_and_grad(loss)(lg, "dot")
    np.testing.assert_allclose(v1, v2, atol=1e-5)
    np.testing.assert_allclose(g1, g2, atol=1e-5)


def test_vocab_padding_preserves_loss_semantics():
    cfg = configs.get_config("smollm-135m", smoke=True)
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "group_ids": jnp.zeros((2,), jnp.int32)}
    y = jnp.full((cfg.n_groups,), 1.0 / cfg.n_groups)

    cfg_pad = dataclasses.replace(cfg, vocab_pad_to=96)
    assert cfg_pad.padded_vocab > cfg.vocab_size
    params_pad = T.init_params(jax.random.PRNGKey(1), cfg_pad)
    assert params_pad["lm_head"].shape[-1] == cfg_pad.padded_vocab

    # build an unpadded model with identical weights (slice the pad rows)
    params_ref = jax.tree.map(lambda x: x, params_pad)
    params_ref["embed"] = params_pad["embed"][: cfg.vocab_size]
    params_ref["lm_head"] = params_pad["lm_head"][:, : cfg.vocab_size]

    l_pad = lm.lm_minimax_loss(params_pad, y, batch, cfg_pad)
    l_ref = lm.lm_minimax_loss(params_ref, y, batch, cfg)
    np.testing.assert_allclose(l_pad, l_ref, atol=1e-5)
    # both CE impls agree on the padded model
    l_dot = lm.lm_minimax_loss(
        params_pad, y, batch, dataclasses.replace(cfg_pad, ce_impl="dot"))
    np.testing.assert_allclose(l_pad, l_dot, atol=1e-5)


def test_unrolled_stages_match_scan():
    cfg = configs.get_config("granite-3-8b", smoke=True)
    cfg4 = dataclasses.replace(
        cfg, stages=(dataclasses.replace(cfg.stages[0], repeat=4),))
    params = T.init_params(jax.random.PRNGKey(0), cfg4)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    l_scan, _, _ = T.forward(params, cfg4, toks)
    l_unroll, _, _ = T.forward(
        params, dataclasses.replace(cfg4, use_scan=False), toks)
    np.testing.assert_allclose(l_scan, l_unroll, atol=2e-5)
