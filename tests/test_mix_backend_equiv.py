"""ShardMapBackend == StackedBackend, under 8 (virtual) devices.

The in-process tests below need a multi-device jax runtime; they skip
themselves on the default single-CPU tier-1 run and are exercised two ways:

* ``test_equivalence_under_8_forced_devices`` re-runs this module in a
  subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
  (always runs, so tier-1 covers the whole matrix);
* the CI ``equiv-8dev`` job sets the same flag for the parent process and
  runs the module directly.

Equivalence levels asserted:

* **bit-identical** (fp32, jitted): the exact clean-channel mix on ring and
  full topologies for k in {1, 3}, the fused int8 ``quant_ring_hop``, and
  the full EF-int8 CommEngine round on the unfused path.  Per-row combines
  are expression-identical across backends, so compiled results match to
  the bit.
* **ulp-tolerance** (atol 1e-6): composite programs whose surrounding
  elementwise chains cross different fusion boundaries (the fused engine
  round, faulty-channel mixing) — XLA's FMA contraction may round 1-2 ulp
  differently there even though every hop's math is identical.

Plus the structural guarantee: the ring hop's jaxpr contains ``ppermute``
and NO ``dot_general`` / dense contraction — neighbour exchange only.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms import CommEngine, CommSpec
from repro.comms.backend import ShardMapBackend, StackedBackend
from repro.comms.channel import ChannelModel
from repro.core.gossip import GossipSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh():
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices())[:8].reshape(8), ("node",))


def _pod_mesh():
    """2x4 virtual pods: the gossip ring linearizes ("pod", "node")
    row-major into one 8-device ring spanning both pods."""
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices())[:8].reshape(2, 4), ("pod", "node"))


def _tree(n, seed=0):
    key = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(key, (n, 37, 13), jnp.float32),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 129),
                                   jnp.float32)}


def _assert_bit_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert bool(jnp.all(x == y)), \
            f"max |diff| = {float(jnp.max(jnp.abs(x - y)))}"


def _assert_close(a, b, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol)


# ---------------------------------------------------------------------------
# exact mix: bit identity
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("topology", ["ring", "full"])
@pytest.mark.parametrize("n", [8, 16])      # 1 and 2 node rows per device
@pytest.mark.parametrize("k", [1, 3])
def test_exact_mix_bit_identical(topology, n, k):
    spec = GossipSpec(topology=topology, n_nodes=n, k_steps=k)
    st, sm = StackedBackend(), ShardMapBackend(_mesh(), axis="node")
    tree = _tree(n)
    a = jax.jit(lambda t: st.mix(spec, t, k))(tree)
    b = jax.jit(lambda t: sm.mix(spec, t, k))(tree)
    _assert_bit_equal(a, b)


@multi_device
def test_ring_hop_is_permute_only_no_dense_contraction(assert_jaxpr_rule):
    """The acceptance-criterion structural check: for the ring topology the
    shard_map hop is ppermute + elementwise — the dense (n, n) einsum path
    must not appear anywhere in the jaxpr.  (Same coverage as the old
    hand-rolled string asserts, via the repro.analysis comm-schedule rule.)"""
    spec = GossipSpec(topology="ring", n_nodes=8, k_steps=3)
    sm = ShardMapBackend(_mesh(), axis="node")
    assert_jaxpr_rule("comm-schedule", name="ring_hop",
                      fn=lambda t: sm.mix(spec, t, 3), args=(_tree(8),),
                      min_ppermute=1, forbid_primitives=("dot_general",))
    # the dense fallback, by contrast, does contract (sanity of the check)
    full = GossipSpec(topology="full", n_nodes=8, k_steps=1)
    with pytest.raises(AssertionError, match="dot_general"):
        assert_jaxpr_rule("comm-schedule", name="dense_fallback",
                          fn=lambda t: sm.mix(full, t, 1), args=(_tree(8),),
                          forbid_primitives=("dot_general",))


@multi_device
def test_quant_ring_hop_bit_identical():
    spec = GossipSpec(topology="ring", n_nodes=8, k_steps=1)
    st, sm = StackedBackend(), ShardMapBackend(_mesh(), axis="node")
    key = jax.random.PRNGKey(3)
    q = jax.random.randint(key, (8, 481), -127, 128, jnp.int8)
    sc = 0.01 * jax.random.uniform(jax.random.fold_in(key, 1), (8, 1)) + 1e-4
    a = jax.jit(lambda q, s: st.quant_ring_hop(spec, q, s))(q, sc)
    b = jax.jit(lambda q, s: sm.quant_ring_hop(spec, q, s))(q, sc)
    _assert_bit_equal(a, b)


# ---------------------------------------------------------------------------
# multi-pod rings: ShardMapBackend(axis=("pod","node")) on a 2x4 mesh
# ---------------------------------------------------------------------------


@multi_device
@pytest.mark.parametrize("topology", ["ring", "full"])
@pytest.mark.parametrize("n", [8, 16])
@pytest.mark.parametrize("k", [1, 3])
def test_virtual_pod_mix_bit_identical(topology, n, k):
    """The ROADMAP's multi-pod case: a ring over the linearized
    ("pod", "node") axes of a 2x4 mesh must stay bit-identical to the
    stacked reference, exactly like the flat 8-device node axis."""
    spec = GossipSpec(topology=topology, n_nodes=n, k_steps=k)
    st, sm = StackedBackend(), ShardMapBackend(_pod_mesh(),
                                               axis=("pod", "node"))
    assert sm.axis_size == 8
    tree = _tree(n, seed=2)
    a = jax.jit(lambda t: st.mix(spec, t, k))(tree)
    b = jax.jit(lambda t: sm.mix(spec, t, k))(tree)
    _assert_bit_equal(a, b)


@multi_device
def test_virtual_pod_quant_hop_and_channel():
    spec = GossipSpec(topology="ring", n_nodes=8, k_steps=1)
    st, sm = StackedBackend(), ShardMapBackend(_pod_mesh(),
                                               axis=("pod", "node"))
    key = jax.random.PRNGKey(5)
    q = jax.random.randint(key, (8, 355), -127, 128, jnp.int8)
    sc = 0.01 * jax.random.uniform(jax.random.fold_in(key, 1), (8, 1)) + 1e-4
    _assert_bit_equal(jax.jit(lambda q, s: st.quant_ring_hop(spec, q, s))(q, sc),
                      jax.jit(lambda q, s: sm.quant_ring_hop(spec, q, s))(q, sc))
    ch = ChannelModel.for_gossip(spec, CommSpec(
        drop_rate=0.25, straggler_rate=0.1, schedule="matching"))
    tree = _tree(8, seed=3)
    ckey = jax.random.PRNGKey(13)
    a = jax.jit(lambda t: st.mix_channel(spec, ch, t, 4, ckey, 3))(tree)
    b = jax.jit(lambda t: sm.mix_channel(spec, ch, t, 4, ckey, 3))(tree)
    _assert_close(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# CommEngine: EF-int8 compression and channel faults under both backends
# ---------------------------------------------------------------------------


def _engine_round(backend, comm, n=8, steps=3):
    spec = GossipSpec(topology="ring", n_nodes=n, k_steps=1, comm=comm,
                      backend=backend)
    eng = CommEngine(spec)
    tree = _tree(n)
    cs = eng.init_state({"x": tree})
    out, _ = jax.jit(lambda c, t: eng.mix(c, "x", t, steps=steps, rnd=2))(
        cs, tree)
    return out


@multi_device
def test_ef_int8_engine_bit_identical_unfused():
    comm = CommSpec(compressor="int8", gamma=0.9, fuse_kernel=False)
    a = _engine_round(StackedBackend(), comm)
    b = _engine_round(ShardMapBackend(_mesh(), axis="node"), comm)
    _assert_bit_equal(a, b)


@multi_device
@pytest.mark.parametrize("comm", [
    CommSpec(compressor="int8", gamma=0.9),                    # fused hop
    CommSpec(compressor="int8", gamma=0.9, drop_rate=0.2),     # + faults
    CommSpec(drop_rate=0.2, straggler_rate=0.1,
             schedule="round_robin"),                          # channel-only
])
def test_engine_equivalent_across_backends(comm):
    a = _engine_round(StackedBackend(), comm)
    b = _engine_round(ShardMapBackend(_mesh(), axis="node"), comm)
    _assert_close(a, b, atol=1e-6)


@multi_device
@pytest.mark.parametrize("steps", [1, 3])
def test_faulty_channel_equivalent(steps):
    """Same W_t sample path: per-link ppermute weights == dense W_t einsum."""
    spec = GossipSpec(topology="ring", n_nodes=8, k_steps=1)
    ch = ChannelModel.for_gossip(spec, CommSpec(
        drop_rate=0.25, straggler_rate=0.1, schedule="matching"))
    st, sm = StackedBackend(), ShardMapBackend(_mesh(), axis="node")
    tree = _tree(8)
    key = jax.random.PRNGKey(11)
    a = jax.jit(lambda t: st.mix_channel(spec, ch, t, 5, key, steps))(tree)
    b = jax.jit(lambda t: sm.mix_channel(spec, ch, t, 5, key, steps))(tree)
    _assert_close(a, b, atol=1e-6)


@multi_device
def test_drgda_step_equivalent_across_backends():
    """Two DRGDA steps end-to-end: the optimizer math never sees the
    backend, so iterates must agree to fp32 roundoff."""
    from repro.core import OPTIMIZERS
    from repro.core.gda import broadcast_to_nodes
    from repro.core import manifolds as M
    from repro.core.minimax import MinimaxProblem

    d, r, ngrp, n = 8, 2, 3, 8

    def loss_fn(x, y, batch):
        proj = batch["z"] @ x["w"]
        per_group = jnp.stack([jnp.mean(proj ** 2)] * ngrp) + x["bias"].sum()
        return jnp.sum(y * per_group) - 0.5 * jnp.sum(y ** 2)

    problem = MinimaxProblem(
        loss_fn=loss_fn,
        manifold_map={"w": "stiefel", "bias": "euclidean"},
        project_y=lambda y: jnp.clip(y, 0.0, 1.0))
    x0 = {"w": M.random_stiefel(jax.random.PRNGKey(0), d, r),
          "bias": jnp.zeros((4,))}
    xs = broadcast_to_nodes(x0, n)
    ys = jnp.full((n, ngrp), 1.0 / ngrp)
    batch = {"z": jax.random.normal(jax.random.PRNGKey(1), (n, 16, d))}

    finals = []
    for backend in (StackedBackend(), ShardMapBackend(_mesh(), axis="node")):
        spec = GossipSpec(topology="ring", n_nodes=n, k_steps=2,
                          backend=backend)
        opt = OPTIMIZERS["drgda"](problem, spec)
        state = opt.init(xs, ys, batch)
        step = opt.make_step(donate=False)
        for _ in range(2):
            state, _ = step(state, batch)
        finals.append(state)
    _assert_close(finals[0].x, finals[1].x, atol=1e-6)
    _assert_close({"y": finals[0].y}, {"y": finals[1].y}, atol=1e-6)


@multi_device
def test_degenerate_small_n_falls_back_to_stacked_everywhere():
    """n_nodes smaller than the mesh node axis must take the stacked paths
    for exact, channel, and quant mixing — never the shard_map block math."""
    spec = GossipSpec(topology="ring", n_nodes=2, k_steps=1)
    st, sm = StackedBackend(), ShardMapBackend(_mesh(), axis="node")
    tree = _tree(2)
    _assert_bit_equal(jax.jit(lambda t: st.mix(spec, t, 2))(tree),
                      jax.jit(lambda t: sm.mix(spec, t, 2))(tree))
    ch = ChannelModel.for_gossip(spec, CommSpec(drop_rate=0.3))
    key = jax.random.PRNGKey(0)
    a = jax.jit(lambda t: st.mix_channel(spec, ch, t, 1, key, 2))(tree)
    b = jax.jit(lambda t: sm.mix_channel(spec, ch, t, 1, key, 2))(tree)
    _assert_bit_equal(a, b)


# ---------------------------------------------------------------------------
# subprocess driver: force 8 host devices and run the matrix above
# ---------------------------------------------------------------------------


def test_equivalence_under_8_forced_devices():
    if len(jax.devices()) >= 8:
        pytest.skip("already multi-device; in-process tests cover this")
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "not forced_devices"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=os.path.join(REPO, "tests"))
    assert out.returncode == 0, \
        (out.stdout[-3000:] + "\n" + out.stderr[-2000:])
    assert "skipped" not in out.stdout.splitlines()[-1] or \
        " 0 skipped" in out.stdout.splitlines()[-1]
