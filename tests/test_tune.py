"""Autotune cache: modes, round-trip, hysteresis, accuracy gate.

Everything runs against a throwaway cache dir (``REPRO_TUNE_DIR``) so the
repo's ``experiments/tune/`` is never touched; searches use tiny shapes so
the timed sweep stays in the low seconds on CPU.
"""
import json
import os

import jax.numpy as jnp
import pytest

from repro.kernels import ops, tune

TINY = {
    "ring_mix": (16, 256),
    "multi_hop_mix": (8, 256),
    # d=128 so the SPACES block_d=128 candidates stay feasible and the
    # ns_iters axis is actually searched (and accuracy-gated)
    "fused_retract": (128, 8),
}


@pytest.fixture()
def tune_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_DIR", str(tmp_path))
    tune._MEM.clear()
    yield str(tmp_path)
    tune._MEM.clear()


def test_mode_knob(monkeypatch):
    monkeypatch.delenv("REPRO_TUNE", raising=False)
    assert tune.mode() == "load"
    monkeypatch.setenv("REPRO_TUNE", "search")
    assert tune.mode() == "search"
    monkeypatch.setenv("REPRO_TUNE", "banana")
    with pytest.raises(ValueError):
        tune.mode()


def test_key_is_stable_and_extra_sorted():
    k = tune.key("ring_mix", (64, 1024), "float32")
    assert k == "ring_mix|64x1024|float32"
    ka = tune.key("multi_hop_mix", (16, 128), "float32",
                  {"hops": 3})
    assert ka.endswith("|hops=3")


def test_default_for_shape_steps_down_ladder():
    # nominal block_rows=256 infeasible for 16 rows -> 16 divides at 16? the
    # ladder tries 128, 64, 32, 16
    assert tune._default_for_shape("ring_mix", (16, 256)) \
        == {"block_rows": 16}
    assert tune._default_for_shape("ring_mix", (512, 256)) \
        == {"block_rows": 256}
    # prime rows: nothing on the ladder divides -> the shape itself
    assert tune._default_for_shape("ring_mix", (7, 256)) \
        == {"block_rows": 7}
    assert tune._default_for_shape("multi_hop_mix", (8, 130)) \
        == {"block_f": 130}
    assert tune._default_for_shape("fused_retract", (64, 8)) \
        == {"block_d": 64, "ns_iters": 20}
    assert tune._default_for_shape("fused_retract", (512, 8)) \
        == {"block_d": 256, "ns_iters": 20}


def test_off_mode_never_reads(tune_dir, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert tune.lookup("ring_mix", TINY["ring_mix"], "float32") is None


def test_load_mode_never_searches(tune_dir, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "load")
    assert tune.lookup("ring_mix", TINY["ring_mix"], "float32") is None
    assert not os.path.exists(tune.cache_path())


def test_search_round_trip_second_run_pure_load(tune_dir, monkeypatch):
    """The CI tune job's core assertion: a search populates the cache; the
    same lookup again serves it without another search (``searches`` flat).
    """
    monkeypatch.setenv("REPRO_TUNE", "search")
    cfg = tune.lookup("fused_retract", TINY["fused_retract"], "float32")
    assert cfg is not None and "ns_iters" in cfg
    assert os.path.exists(tune.cache_path())
    with open(tune.cache_path()) as f:
        cache = json.load(f)
    assert cache["searches"] == 1
    k = tune.key("fused_retract", TINY["fused_retract"], "float32")
    entry = cache["entries"][k]
    assert entry["default_config"] == \
        tune._default_for_shape("fused_retract", TINY["fused_retract"])
    assert entry["roofline"]  # placed on the roofline for the report

    again = tune.lookup("fused_retract", TINY["fused_retract"], "float32")
    assert again == cfg
    with open(tune.cache_path()) as f:
        assert json.load(f)["searches"] == 1   # pure load, no re-search

    # and load mode serves the same entry
    monkeypatch.setenv("REPRO_TUNE", "load")
    assert tune.lookup("fused_retract", TINY["fused_retract"],
                       "float32") == cfg


def test_accuracy_gate_and_candidates_recorded(tune_dir, monkeypatch):
    """ns_iters candidates that drift past ACCURACY_RTOL vs the default are
    recorded but excluded from the winner."""
    monkeypatch.setenv("REPRO_TUNE", "search")
    entry = tune.autotune("fused_retract", TINY["fused_retract"], "float32")
    gated = [c for c in entry["candidates"] if "accurate" in c]
    assert gated, "non-default ns_iters candidates must be accuracy-checked"
    for c in gated:
        assert "max_abs_err" in c
    winner = entry["config"]
    rec = next(c for c in entry["candidates"] if c["config"] == winner)
    assert rec.get("accurate", True)


def test_hysteresis_keeps_default_on_noise(tune_dir, monkeypatch):
    """Block-shape-only kernels are no-ops on the oracle path, so the ref
    dedupe collapses them onto the default — the entry must come back with
    the default config and ~0 speedup rather than chasing timer noise."""
    monkeypatch.setenv("REPRO_TUNE", "search")
    if tune._dispatch_impl() != "ref":
        pytest.skip("oracle-path dedupe only applies off-TPU")
    entry = tune.autotune("ring_mix", TINY["ring_mix"], "float32")
    assert entry["config"] == \
        tune._default_for_shape("ring_mix", TINY["ring_mix"])
    assert len(entry["candidates"]) == 1


def test_ops_consume_tuned_config(tune_dir, monkeypatch):
    """End to end: a searched fused_retract entry with a non-default
    ns_iters is visibly consumed by ops.fused_retract (the recorded op count
    scales with ns_iters)."""
    from repro.obs import estimates as est

    d, r = TINY["fused_retract"]
    monkeypatch.setenv("REPRO_TUNE", "search")
    entry = tune.autotune("fused_retract", (d, r), "float32")

    import jax
    x, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(0), (d, r)))
    g = jax.random.normal(jax.random.PRNGKey(1), (d, r))

    def recorded_ops():
        with est.collect() as c:
            ops.fused_retract(x, g)
        return c.snapshot()["fused_retract"]["ops"]

    monkeypatch.setenv("REPRO_TUNE", "load")
    tuned_ops = recorded_ops()
    monkeypatch.setenv("REPRO_TUNE", "off")
    default_ops = recorded_ops()
    ns = entry["config"]["ns_iters"]
    if ns != tune.DEFAULTS["fused_retract"]["ns_iters"]:
        assert tuned_ops != default_ops
    assert tuned_ops == est.fused_retract_est(d, r, ns_iters=ns).ops


def test_cli_demo_and_force(tune_dir, monkeypatch, capsys):
    monkeypatch.setenv("REPRO_TUNE", "search")
    assert tune.main(["--kernel", "ring_mix:16x256"]) == 0
    out = capsys.readouterr().out
    assert "ring_mix|16x256|float32" in out
    with open(tune.cache_path()) as f:
        assert json.load(f)["searches"] == 1
    # cache hit: no new search
    assert tune.main(["--kernel", "ring_mix:16x256"]) == 0
    with open(tune.cache_path()) as f:
        assert json.load(f)["searches"] == 1
    # --force re-searches
    assert tune.main(["--kernel", "ring_mix:16x256", "--force"]) == 0
    with open(tune.cache_path()) as f:
        assert json.load(f)["searches"] == 2


def test_clear_removes_cache(tune_dir, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE", "search")
    tune.autotune("ring_mix", TINY["ring_mix"], "float32")
    assert os.path.exists(tune.cache_path())
    tune.clear()
    assert not os.path.exists(tune.cache_path())
