"""Objective-layer tests: simplex geometry, closed-form inner maximizers,
strong concavity, group losses, CNN fair/DRO problems."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro import configs
from repro.core.minimax import project_simplex
from repro.data.synthetic import ClassificationStream, TokenStream
from repro.models import transformer as T
from repro.objectives import fair, lm

SET = dict(deadline=None, max_examples=20)


@given(st.integers(2, 12), st.integers(0, 10000))
@settings(**SET)
def test_project_simplex_properties(k, seed):
    y = jax.random.normal(jax.random.PRNGKey(seed), (k,)) * 3.0
    p = project_simplex(y)
    assert float(jnp.abs(p.sum() - 1.0)) < 1e-5
    assert float(p.min()) >= -1e-7
    # projection of a simplex point is itself
    q = jax.nn.softmax(y)
    np.testing.assert_allclose(project_simplex(q), q, atol=1e-5)


@given(st.integers(2, 8), st.integers(0, 1000))
@settings(**SET)
def test_project_simplex_is_euclidean_projection(k, seed):
    """Check against brute-force optimality: the projection must be at
    least as close as softmax / uniform / one-hot candidates."""
    y = jax.random.normal(jax.random.PRNGKey(seed), (k,)) * 2.0
    p = project_simplex(y)
    d_p = float(jnp.sum((y - p) ** 2))
    for cand in [jax.nn.softmax(y), jnp.full((k,), 1.0 / k),
                 jax.nn.one_hot(jnp.argmax(y), k)]:
        assert d_p <= float(jnp.sum((y - cand) ** 2)) + 1e-5


def test_group_losses_fallback():
    per_seq = jnp.array([1.0, 2.0, 3.0, 4.0])
    gids = jnp.array([0, 0, 2, 2])
    lg = lm.group_losses(per_seq, gids, 4)
    np.testing.assert_allclose(lg, [1.5, 2.5, 3.5, 2.5], atol=1e-6)


def test_lm_loss_strongly_concave_in_y():
    cfg = configs.get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(2, 2, 16, cfg.vocab_size, n_groups=cfg.n_groups)
    batch = {k: jnp.asarray(v[0]) for k, v in stream.batch(0).items()}
    f = functools.partial(lm.lm_minimax_loss, params, batch=batch, cfg=cfg)
    hess = jax.hessian(f)(jnp.full((cfg.n_groups,), 1.0 / cfg.n_groups))
    eig = np.linalg.eigvalsh(np.asarray(hess))
    # strong concavity with modulus 2*rho (loss part is linear in y)
    assert eig.max() <= -2.0 * cfg.rho + 1e-4


def test_lm_y_star_is_argmax():
    cfg = configs.get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(3, 2, 16, cfg.vocab_size, n_groups=cfg.n_groups)
    batches = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    y_opt = lm.lm_y_star(params, batches, cfg)
    assert float(jnp.abs(y_opt.sum() - 1.0)) < 1e-5

    def g_val(y):
        vals = jax.vmap(lambda b: lm.lm_minimax_loss(
            params, y, b, cfg))( batches)
        return float(jnp.mean(vals))

    v_star = g_val(y_opt)
    for seed in range(5):
        y_alt = project_simplex(
            y_opt + 0.1 * jax.random.normal(jax.random.PRNGKey(seed),
                                            y_opt.shape))
        assert g_val(y_alt) <= v_star + 1e-4


def test_fair_cnn_problem_end_to_end():
    stream = ClassificationStream(n_nodes=2, batch_per_node=16)
    params = fair.init_cnn(jax.random.PRNGKey(0), image_hw=stream.image_hw)
    prob = fair.make_fair_problem(params)
    batch = {k: jnp.asarray(v[0]) for k, v in stream.batch(0).items()}
    u = jnp.full((3,), 1 / 3)
    val = prob.value(params, u, batch)
    assert np.isfinite(float(val))
    gx, gy = prob.grads(params, u, batch)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(gx))
    # fc leaves are Stiefel, conv leaves are not
    assert prob.stiefel_mask == {"conv1": False, "conv2": False,
                                 "fc1": True, "head": True}


def test_dro_y_star_closed_form():
    stream = ClassificationStream(n_nodes=2, batch_per_node=16)
    params = fair.init_cnn(jax.random.PRNGKey(0), image_hw=stream.image_hw)
    prob = fair.make_dro_problem(params)
    batches = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
    p_opt = prob.y_star(params, batches)

    def g_val(p):
        vals = jax.vmap(lambda b: prob.loss_fn(params, p, b))(batches)
        return float(jnp.mean(vals))

    v_star = g_val(p_opt)
    for seed in range(5):
        p_alt = project_simplex(
            p_opt + 0.2 * jax.random.normal(jax.random.PRNGKey(seed),
                                            p_opt.shape))
        assert g_val(p_alt) <= v_star + 1e-4
