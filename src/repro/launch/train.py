"""Training launcher.

Two regimes:

* ``--device-grid host``   (default here): run REAL steps on the local
  device(s) with a reduced config — the end-to-end driver used by
  examples/decentralized_llm_pretrain.py and the integration tests.
* ``--device-grid pod|2pod``: build the production mesh and execute the
  jitted SPMD step (requires the corresponding real TPU slice; on this CPU
  container use ``repro.launch.dryrun`` instead, which only lowers).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
      --steps 50 --optimizer drsgda --nodes 8
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint, configs
from repro.core.gda import GDAHyper
from repro.core.metric import convergence_metric
from repro.data.synthetic import TokenStream
from repro.launch.steps import TrainSpec, build_trainer, init_train_state
from repro.obs import Telemetry


def _span(telemetry, name, **tags):
    import contextlib
    if telemetry is None:
        return contextlib.nullcontext()
    return telemetry.span(name, **tags)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--optimizer", default="drsgda",
                    choices=["drgda", "drsgda", "gt-gda", "gnsd-a", "dm-hsgd"])
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-per-node", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--beta", type=float, default=0.02)
    ap.add_argument("--eta", type=float, default=0.05)
    ap.add_argument("--topology", default="ring",
                    choices=["ring", "full", "torus", "star"])
    ap.add_argument("--eval-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-json", default="")
    ap.add_argument("--telemetry", action="store_true",
                    help="thread wire counters through the jitted step and "
                         "stream the convergence dashboard to an event log")
    ap.add_argument("--telemetry-dir", default="experiments/telemetry")
    ap.add_argument("--telemetry-run", default="",
                    help="run name for the event log / trace files "
                         "(default: <optimizer>-<arch>)")
    ap.add_argument("--churn", default="static",
                    choices=["static", "random"],
                    help="elastic-gossip churn schedule (random: seeded "
                         "per-round leave/rejoin Markov draws)")
    ap.add_argument("--churn-leave-rate", type=float, default=0.05)
    ap.add_argument("--churn-join-rate", type=float, default=0.5)
    ap.add_argument("--tau", type=int, default=0,
                    help="elastic stale-hop tolerance (rounds)")
    args = ap.parse_args(argv)

    telemetry = None
    if args.telemetry:
        telemetry = Telemetry(
            run=args.telemetry_run or f"{args.optimizer}-{args.arch}",
            out_dir=args.telemetry_dir, flush_every=args.eval_every)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    hyper = GDAHyper(alpha=args.alpha, beta=args.beta, eta=args.eta)
    elastic = None
    if args.churn != "static" or args.tau > 0:
        from repro.comms.elastic import ChurnSchedule, ElasticSpec
        elastic = ElasticSpec(
            churn=ChurnSchedule(kind=args.churn,
                                leave_rate=args.churn_leave_rate,
                                join_rate=args.churn_join_rate),
            tau=args.tau, seed=args.seed)
    spec = TrainSpec(optimizer=args.optimizer, topology=args.topology,
                     elastic=elastic, telemetry=telemetry, hyper=hyper)
    opt, problem = build_trainer(cfg, args.nodes, spec)

    stream = TokenStream(n_nodes=args.nodes, batch_per_node=args.batch_per_node,
                         seq_len=args.seq_len, vocab_size=cfg.vocab_size,
                         n_groups=cfg.n_groups, n_codebooks=cfg.n_codebooks,
                         seed=args.seed)

    def to_jax(b):
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.frontend is not None:
            key = jax.random.PRNGKey(hash((args.seed, "fe")) % (2 ** 31))
            out["frontend_embeds"] = 0.1 * jax.random.normal(
                key, (args.nodes, args.batch_per_node, cfg.frontend.n_tokens,
                      cfg.frontend.embed_dim))
        return out

    batch0 = to_jax(stream.batch(0))
    with _span(telemetry, "init"):
        state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt,
                                 args.nodes, batch0)
    step_fn = opt.make_step(donate=True)

    history = []
    t_start = time.time()
    with _span(telemetry, "train", steps=args.steps):
        for t in range(args.steps):
            batch = to_jax(stream.batch(t + 1))
            state, metrics = step_fn(state, batch)
            if (t + 1) % args.eval_every == 0 or t == args.steps - 1:
                with _span(telemetry, "eval", step=t + 1):
                    m = convergence_metric(problem, state.x, state.y, batch)
                row = {
                    "step": t + 1,
                    "loss": float(metrics.loss),
                    "grad_norm_x": float(metrics.grad_norm_x),
                    "consensus_x": float(metrics.consensus_x),
                    "M_t": float(m["M_t"]),
                    "stiefel_residual": float(m["stiefel_residual"]),
                    "wall_s": round(time.time() - t_start, 1),
                }
                history.append(row)
                print(json.dumps(row), flush=True)
                if telemetry is not None:
                    telemetry.dashboard(problem, state.x, state.y, batch,
                                        step=t + 1,
                                        extra={"loss": row["loss"]})
                    mem = getattr(state.comm, "elastic", None)
                    if mem is not None:
                        act = np.asarray(mem.active)
                        prev = np.asarray(mem.prev_active)
                        telemetry.event("membership", {
                            "live": int(act.sum()),
                            "joins": int(((act > 0) & (prev == 0)).sum()),
                            "leaves": int(((act == 0) & (prev > 0)).sum()),
                            "active": act.astype(int).tolist(),
                        }, step=t + 1)
            if args.checkpoint_every and (t + 1) % args.checkpoint_every == 0 \
                    and args.checkpoint_dir:
                with _span(telemetry, "checkpoint", step=t + 1):
                    checkpoint.save(args.checkpoint_dir, t + 1, state.x)

    if telemetry is not None:
        paths = telemetry.export()
        print(json.dumps({"telemetry": paths}), flush=True)
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(history, f, indent=1)
    # success = finite loss and preserved feasibility
    ok = np.isfinite(history[-1]["loss"]) and \
        history[-1]["stiefel_residual"] < 1e-2
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
