"""§Perf hillclimb driver.

Each experiment = (base arch × shape × mesh) + a list of named config
transformations (knobs).  For every variant we derive the scaled roofline
terms (differential analysis) and the top collective ops, and append the
record to experiments/perf/<name>.json.  The narrative
hypothesis → change → before → after lives in EXPERIMENTS.md §Perf.

Run:  PYTHONPATH=src python -m repro.launch.perf --exp deepseek_moe

The many-host-device XLA override only applies on the ``__main__`` driver
path (see :func:`_set_dryrun_xla_flags`) — importing this module never
touches the environment, and a user-set ``XLA_FLAGS`` always wins.
"""
import argparse
import dataclasses
import json
import os
import sys
import time


from repro import configs
from repro.configs.base import INPUT_SHAPES, MeshPlan, MoESpec  # noqa: F401
from repro.launch import dryrun_lib, roofline

#: default driver-path flags — the dry-run fakes a 512-device host platform
DEFAULT_DRYRUN_XLA_FLAGS = "--xla_force_host_platform_device_count=512"


def _set_dryrun_xla_flags() -> str:
    """Install the dry-run device-count flags, driver path only.

    Precedence: an existing ``XLA_FLAGS`` is left untouched (the user knows
    best), else ``REPRO_DRYRUN_XLA_FLAGS``, else the 512-device default.
    Must run before the first ``jax`` backend initialization to take effect.
    """
    if not os.environ.get("XLA_FLAGS"):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
            or DEFAULT_DRYRUN_XLA_FLAGS)
    return os.environ["XLA_FLAGS"]


def analyze(cfg, shape_name: str, mesh_kind: str = "single", *,
            optimizer: str = "drsgda", top_n: int = 10) -> dict:
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    terms = dryrun_lib.scaled_roofline_terms(cfg, shape, mesh_kind,
                                             optimizer=optimizer)
    # top collectives from the depth-1 unrolled variant (source attribution)
    v0 = dataclasses.replace(
        cfg, stages=tuple(dataclasses.replace(s, repeat=1)
                          for s in cfg.stages), use_scan=False)
    lowered, chips, _ = dryrun_lib._lower_one(v0, shape, mesh_kind,
                                              optimizer=optimizer)
    top = roofline.top_collectives(lowered.compile().as_text(), top_n)
    return {"terms": terms.as_dict(), "top_collectives": top,
            "wall_s": round(time.time() - t0, 1)}


# ---------------------------------------------------------------------------
# knob transformations
# ---------------------------------------------------------------------------


def moe_dispatch_groups(g: int, spmd_axis: str = "", expert_axis: str = ""):
    def tf(cfg):
        stages = tuple(
            dataclasses.replace(st, blocks=tuple(
                dataclasses.replace(b, moe=dataclasses.replace(
                    b.moe, dispatch_groups=g, dispatch_spmd_axis=spmd_axis,
                    expert_shard_axis=expert_axis))
                if b.kind == "moe_attn" else b
                for b in st.blocks))
            for st in cfg.stages)
        return dataclasses.replace(cfg, stages=stages)
    return tf


def ce_dot(cfg):
    return dataclasses.replace(cfg, ce_impl="dot")


def mesh_plan(node: int, fsdp: int, model: int):
    def tf(cfg):
        return dataclasses.replace(cfg,
                                   mesh_plan=MeshPlan(node, fsdp, model))
    return tf


def no_remat(cfg):
    return dataclasses.replace(cfg, remat=False)


def vocab_pad(m: int = 256):
    def tf(cfg):
        return dataclasses.replace(cfg, vocab_pad_to=m)
    return tf


def compose(*tfs):
    def tf(cfg):
        for t in tfs:
            cfg = t(cfg)
        return cfg
    return tf


# ---------------------------------------------------------------------------
# experiments — three selected pairs (§Perf)
# ---------------------------------------------------------------------------

EXPERIMENTS = {
    # pair 1: most collective-bound — deepseek train
    "deepseek_moe": {
        "arch": "deepseek-v2-236b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", lambda c: c),
            ("local_dispatch_g8", moe_dispatch_groups(8)),
            ("seq_dispatch", moe_dispatch_groups(-1)),
            ("seq_spmd_fsdp", moe_dispatch_groups(-1, "fsdp")),
            ("expert_pin", moe_dispatch_groups(1, "", "model")),
            ("expert_pin+seq_spmd", moe_dispatch_groups(-1, "fsdp", "model")),
        ],
    },
    # pair 2: paper-technique-representative — granite pure-DP decentralized
    "granite_gossip": {
        "arch": "granite-3-2b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", lambda c: c),
            ("ce_dot", ce_dot),
            ("ce_dot+fsdp4", compose(ce_dot, mesh_plan(4, 4, 16))),
            ("ce_dot+tp4", compose(ce_dot, mesh_plan(16, 4, 4))),
            ("ce_dot+tp4+vpad", compose(ce_dot, mesh_plan(16, 4, 4),
                                        vocab_pad(256))),
            ("vpad_only", vocab_pad(256)),
        ],
    },
    # pair 3: worst compute fraction — gemma3 train
    "gemma3_train": {
        "arch": "gemma3-27b", "shape": "train_4k", "mesh": "single",
        "variants": [
            ("baseline", lambda c: c),
            ("ce_dot", ce_dot),
            ("ce_dot+tp8_fsdp8", compose(ce_dot, mesh_plan(4, 8, 8))),
            ("ce_dot+node8", compose(ce_dot, mesh_plan(8, 4, 8))),
        ],
    },
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", required=True, choices=list(EXPERIMENTS) + ["all"])
    ap.add_argument("--variant", default="all")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args(argv)

    names = list(EXPERIMENTS) if args.exp == "all" else [args.exp]
    os.makedirs(args.out, exist_ok=True)
    for name in names:
        spec = EXPERIMENTS[name]
        path = os.path.join(args.out, f"{name}.json")
        results = {}
        if os.path.exists(path):
            with open(path) as f:
                results = json.load(f)
        for vname, tf in spec["variants"]:
            if args.variant != "all" and vname != args.variant:
                continue
            if vname in results:
                print(f"[skip] {name}/{vname} (cached)", flush=True)
                continue
            cfg = tf(configs.get_config(spec["arch"]))
            try:
                rec = analyze(cfg, spec["shape"], spec["mesh"])
                results[vname] = rec
                t = rec["terms"]
                print(f"[ok] {name}/{vname}: compute={t['compute_s']:.3e} "
                      f"memory={t['memory_s']:.3e} "
                      f"collective={t['collective_s']:.3e} "
                      f"dominant={t['dominant']} ({rec['wall_s']}s)",
                      flush=True)
            except Exception as e:
                print(f"[FAIL] {name}/{vname}: {type(e).__name__}: {e}",
                      flush=True)
            with open(path, "w") as f:
                json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    _set_dryrun_xla_flags()
    sys.exit(main())
