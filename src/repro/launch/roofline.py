"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), on a selectable hardware model
(default TPU v5e):

  compute_s    = FLOPs_per_device / hw.peak_flops    (bf16 MXU peak)
  memory_s     = bytes_per_device / hw.hbm_bw        (HBM bandwidth)
  collective_s = collective_bytes_per_device / hw.ici_bw  (ICI, per link)

FLOPs / bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
per-device module.  Collective bytes are NOT in cost_analysis: we parse the
optimized HLO and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (counting the
per-device payload each op moves over the interconnect once — a deliberate
first-order model; ring reductions move ~2x, which we note rather than
model).

Pick the hardware with ``REPRO_HW=tpu_v4|tpu_v5e|tpu_v5p`` (or pass a
:class:`HardwareModel` / registry name explicitly to the entry points);
:func:`place` positions any :class:`repro.obs.Estimates` on that roofline.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Optional


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Peak numbers of one accelerator chip for roofline placement."""
    name: str
    peak_flops: float   # bf16 FLOP/s per chip
    hbm_bw: float       # HBM bytes/s per chip
    ici_bw: float       # interconnect bytes/s per link
    # VMEM per core: ~16 MiB on every current TPU generation — the hard
    # budget every pallas_call's resident blocks (inputs + outputs +
    # scratch, double-buffered) must fit inside
    vmem_bytes: int = 16 * 2**20

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte above which a kernel is compute-bound on this chip."""
        return self.peak_flops / self.hbm_bw


#: published per-chip peaks (bf16), keyed by the ``REPRO_HW`` names
HARDWARE = {
    "tpu_v4": HardwareModel("tpu_v4", peak_flops=275e12, hbm_bw=1.2e12,
                            ici_bw=50e9),
    "tpu_v5e": HardwareModel("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                             ici_bw=50e9),
    "tpu_v5p": HardwareModel("tpu_v5p", peak_flops=459e12, hbm_bw=2.77e12,
                             ici_bw=100e9),
}

DEFAULT_HW = "tpu_v5e"


def get_hardware(name: Optional[str] = None) -> HardwareModel:
    """Resolve a hardware model: explicit name > ``REPRO_HW`` env > v5e."""
    name = name or os.environ.get("REPRO_HW") or DEFAULT_HW
    if name not in HARDWARE:
        raise ValueError(f"unknown hardware model {name!r}; "
                         f"choose from {sorted(HARDWARE)}")
    return HARDWARE[name]


def place(est, hw: Optional[HardwareModel] = None) -> dict:
    """Place an analytical kernel estimate (``repro.obs.Estimates`` or any
    object with ``ops``/``mem``/``intensity``) on ``hw``'s roofline."""
    hw = hw or get_hardware()
    attainable = min(hw.peak_flops, hw.hbm_bw * max(est.intensity, 0.0))
    return {
        "hw": hw.name,
        "intensity": est.intensity,
        "ridge_intensity": hw.ridge_intensity,
        "bound": "compute" if est.intensity >= hw.ridge_intensity else "memory",
        "attainable_flops": attainable,
        "time_s": est.ops / attainable if attainable > 0 else 0.0,
    }


# legacy module-level v5e constants — RooflineTerms defaults route through
# get_hardware() now; these remain for external readers of the old API
PEAK_FLOPS = HARDWARE[DEFAULT_HW].peak_flops
HBM_BW = HARDWARE[DEFAULT_HW].hbm_bw
ICI_BW = HARDWARE[DEFAULT_HW].ici_bw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[2,128,512]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result bytes per collective kind from optimized HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_part))
        else:
            b = _shape_bytes(dtype, dims)
        out[kind] += b
    return out


_OP_LINE_RE = re.compile(
    r"^\s*%?\S+\s*=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\((.*)$", re.M)

_META_RE = re.compile(r'op_name="([^"]+)"')


def top_collectives(hlo_text: str, n: int = 12) -> list[dict]:
    """The n largest collective ops with their result bytes and the source
    op_name metadata — the 'profile' a dry-run gives you for §Perf."""
    rows = []
    for m in _OP_LINE_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind, rest = m.groups()
        if tuple_part is not None:
            b = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_part))
            shape = tuple_part[:60]
        else:
            b = _shape_bytes(dtype, dims)
            shape = f"{dtype}[{dims}]"
        meta = _META_RE.search(rest)
        rows.append({"kind": kind, "shape": shape, "bytes": b,
                     "op_name": (meta.group(1)[-120:] if meta else "")})
    rows.sort(key=lambda r: -r["bytes"])
    # merge duplicates (same kind+shape+op_name) with a count
    merged: dict = {}
    for r in rows:
        key = (r["kind"], r["shape"], r["op_name"])
        if key in merged:
            merged[key]["count"] += 1
            merged[key]["total_bytes"] += r["bytes"]
        else:
            merged[key] = {**r, "count": 1, "total_bytes": r["bytes"]}
    out = sorted(merged.values(), key=lambda r: -r["total_bytes"])
    return out[:n]


@dataclasses.dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    collective_bytes_per_dev: float
    collective_breakdown: dict
    chips: int
    hw: Optional[HardwareModel] = None   # None -> get_hardware() (env/default)

    @property
    def _hw(self) -> HardwareModel:
        return self.hw or get_hardware()

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / self._hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / self._hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_dev / self._hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "collective_bytes_per_dev": self.collective_bytes_per_dev,
            "collective_breakdown": self.collective_breakdown,
            "chips": self.chips,
            "hw": self._hw.name,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def derive(compiled, chips: int,
           hw: Optional[HardwareModel] = None) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):             # some backends return [dict]
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    cb = collective_bytes(text)
    return RooflineTerms(
        flops_per_dev=flops,
        bytes_per_dev=byts,
        collective_bytes_per_dev=float(sum(cb.values())),
        collective_breakdown=cb,
        chips=chips,
        hw=hw,
    )


def model_flops(n_params_active: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N_active * D (training); 2 * N * D for inference."""
    return 6.0 * n_params_active * tokens


def useful_fraction(model_fl: float, hlo_flops_global: float) -> Optional[float]:
    if hlo_flops_global <= 0:
        return None
    return model_fl / hlo_flops_global
