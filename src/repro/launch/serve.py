"""Serving driver: batched autoregressive decode of a (consensus) model.

On this CPU container it runs reduced configs for real (examples/
serve_decode.py); on a TPU slice the same step functions are jitted against
the production mesh (see dryrun.py for the lowering path).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.steps import make_serve_step
from repro.models import transformer as T


def generate(cfg, params, prompt_tokens, n_new: int, *,
             frontend_embeds=None, temperature: float = 0.0, seed: int = 0):
    """Greedy/temperature sampling loop: prefill then n_new decode steps."""
    b, s = prompt_tokens.shape[:2]
    cache_len = s + n_new
    logits, _, caches = T.forward(params, cfg, prompt_tokens,
                                  frontend_embeds=frontend_embeds,
                                  mode="prefill", cache_len=cache_len,
                                  last_logits_only=True)
    serve_step = jax.jit(make_serve_step(cfg))
    key = jax.random.PRNGKey(seed)

    def sample(lg, key):
        if temperature <= 0.0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(key, lg / temperature, axis=-1)

    # split before the first draw — sampling with `key` itself and then
    # splitting it would correlate the first token with later ones
    key, sub = jax.random.split(key)
    tok = sample(logits[:, -1], sub)
    out = [tok]
    for i in range(n_new - 1):
        key, sub = jax.random.split(key)
        pos = jnp.full((b,), s + i, jnp.int32)
        if frontend_embeds is not None:
            lg, caches = serve_step(params, tok, pos, caches,
                                    frontend_embeds=frontend_embeds)
        else:
            lg, caches = serve_step(params, tok, pos, caches)
        tok = sample(lg, sub)
        out.append(tok)
    return jnp.stack(out, axis=1)


def _serve_engine(cfg, params, args) -> dict:
    """The paged decode service (``repro.serve``): continuous batching over
    a fixed-slot batch with block-table paged KV pools."""
    from repro.serve import (ContinuousBatchingScheduler, PagedKVSpec,
                             Request, ServeEngine, serve_requests)
    ps = args.page_size
    spec = PagedKVSpec(
        page_size=ps,
        n_pages=args.batch * (-(-(args.prompt_len + args.new_tokens) // ps))
        * 2 + 1,
        max_pages_per_slot=-(-(args.prompt_len + args.new_tokens) // ps))
    engine = ServeEngine(cfg, params, kv_spec=spec, n_slots=args.batch,
                         temperature=args.temperature, seed=args.seed)
    sched = ContinuousBatchingScheduler(args.batch, spec)
    key = jax.random.PRNGKey(args.seed + 1)
    reqs = [Request(prompt=jax.random.randint(
                jax.random.fold_in(key, i), (args.prompt_len,), 0,
                cfg.vocab_size).tolist(),
                    max_new_tokens=args.new_tokens)
            for i in range(args.batch)]
    t0 = time.time()
    fin = serve_requests(engine, sched, reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in fin)
    return {
        "arch": cfg.name, "mode": "paged", "batch": args.batch,
        "new_tokens": args.new_tokens, "wall_s": round(dt, 2),
        "tok_per_s": round(n_tok / dt, 1),
        "sample": fin[0].tokens[:8],
    }


def _serve_legacy(cfg, params, args) -> dict:
    """Contiguous-cache batched decode (the pre-paging path; still the only
    one for MLA / SSM / cross-attention architectures)."""
    key = jax.random.PRNGKey(args.seed)
    shape = (args.batch, args.prompt_len) if cfg.n_codebooks == 1 else \
        (args.batch, args.prompt_len, cfg.n_codebooks)
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = 0.1 * jax.random.normal(
            key, (args.batch, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    t0 = time.time()
    toks = generate(cfg, params, prompt, args.new_tokens,
                    frontend_embeds=fe, temperature=args.temperature,
                    seed=args.seed)
    dt = time.time() - t0
    return {
        "arch": cfg.name, "mode": "legacy", "batch": args.batch,
        "new_tokens": args.new_tokens, "wall_s": round(dt, 2),
        "tok_per_s": round(args.batch * args.new_tokens / dt, 1),
        "sample": toks[0].tolist()[:8],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--legacy", action="store_true",
                    help="force the contiguous-cache decode path")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    if args.legacy:
        res = _serve_legacy(cfg, params, args)
    else:
        try:
            res = _serve_engine(cfg, params, args)
        except ValueError:      # non-GQA architecture: contiguous fallback
            res = _serve_legacy(cfg, params, args)
    print(json.dumps(res))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
