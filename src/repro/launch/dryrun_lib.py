"""Dry-run core: lower + compile every (arch x shape x mesh) combination
with ShapeDtypeStruct inputs and extract memory/cost/collective artifacts.

Import-safe (no device-count side effects): the CLI in ``dryrun.py`` sets
``XLA_FLAGS=--xla_force_host_platform_device_count=...`` BEFORE importing
jax; tests use a subprocess with a smaller count.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Any

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch import mesh as mesh_lib
from repro.launch import roofline
from repro.launch.steps import (abstract_train_state, build_trainer,
                                make_prefill_step, make_serve_step)
from repro.models import transformer as T
from repro.sharding import partition


def _param_count(cfg: ModelConfig, dtype=jnp.bfloat16) -> int:
    shapes = jax.eval_shape(lambda k: T.init_params(k, cfg, dtype),
                            jax.random.PRNGKey(0))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def _active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k of routed experts + shared)."""
    total = _param_count(cfg)
    for st in cfg.stages:
        for b in st.blocks:
            if b.kind == "moe_attn":
                f = b.moe.d_expert or cfg.d_ff
                per_expert = 3 * cfg.d_model * f
                routed = b.moe.n_experts * per_expert
                active = b.moe.top_k * per_expert
                total -= st.repeat * (routed - active)
    return total


def _lower_one(cfg: ModelConfig, shape, mesh_kind: str, *,
               optimizer: str = "drsgda", dtype=jnp.bfloat16):
    """Build mesh/shardings and lower the right step for (cfg, shape)."""
    multi_pod = mesh_kind == "multi"
    rec: dict[str, Any] = {}

    if shape.mode == "train":
        mesh = mesh_lib.make_training_mesh(cfg.mesh_plan, multi_pod=multi_pod)
        n_nodes = mesh_lib.total_nodes(cfg.mesh_plan, multi_pod)
        chips = mesh.devices.size
        with mesh:   # model code may carry PartitionSpec constraints
            opt, _ = build_trainer(cfg, n_nodes, optimizer=optimizer,
                                   dtype=dtype)
            batch_specs = configs.input_specs(cfg, shape, n_nodes,
                                              activation_dtype=dtype)
            state_specs = abstract_train_state(cfg, opt, n_nodes, batch_specs,
                                               dtype=dtype)
            state_sh = partition.train_state_shardings(state_specs, mesh,
                                                       multi_pod)
            batch_sh = partition.train_batch_shardings(batch_specs, mesh,
                                                       multi_pod)
            jitted = jax.jit(opt.step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_specs, batch_specs)
        rec["n_nodes"] = n_nodes
        rec["tokens_per_step"] = shape.global_batch * shape.seq_len

    else:
        mesh = mesh_lib.make_serving_mesh(multi_pod=multi_pod)
        chips = mesh.devices.size
        params_specs = jax.eval_shape(
            lambda k: T.init_params(k, cfg, dtype), jax.random.PRNGKey(0))
        params_sh = partition.serve_param_shardings(params_specs, mesh)
        in_specs = configs.input_specs(cfg, shape, activation_dtype=dtype)
        in_sh = partition.serve_batch_shardings(in_specs, mesh, multi_pod)
        has_fe = cfg.frontend is not None
        with mesh:
            if shape.mode == "prefill":
                step = make_prefill_step(cfg, positional_frontend=has_fe)
                args = [params_specs, in_specs["tokens"]]
                shs = [params_sh, in_sh["tokens"]]
                if has_fe:
                    args.append(in_specs["frontend_embeds"])
                    shs.append(in_sh["frontend_embeds"])
                lowered = jax.jit(step, in_shardings=tuple(shs)).lower(*args)
            else:
                step = make_serve_step(cfg, positional_frontend=has_fe)
                args = [params_specs, in_specs["token"], in_specs["position"],
                        in_specs["cache"]]
                shs = [params_sh, in_sh["token"], in_sh["position"],
                       in_sh["cache"]]
                if has_fe:
                    args.append(in_specs["frontend_embeds"])
                    shs.append(in_sh["frontend_embeds"])
                lowered = jax.jit(step, in_shardings=tuple(shs),
                                  donate_argnums=(3,)).lower(*args)
        rec["tokens_per_step"] = shape.global_batch * (
            shape.seq_len if shape.mode == "prefill" else 1)

    return lowered, chips, rec


def scaled_roofline_terms(cfg: ModelConfig, shape, mesh_kind: str, *,
                          optimizer: str = "drsgda",
                          dtype=jnp.bfloat16) -> roofline.RooflineTerms:
    """Differential cost analysis.

    XLA's cost_analysis counts a while-loop body ONCE (not trip_count
    times), so the scanned layer stack is under-counted.  We compile
    shallow UNROLLED variants — all stages at repeat=1, then each
    multi-repeat stage at repeat=2 — and extrapolate linearly:

        total = cost(V0) + sum_s (R_s - 1) * (cost(V_s) - cost(V0))

    Exact for uniform supercells (our stages are uniform by construction);
    gossip/tracking costs on per-layer parameters scale with the layer
    count and are captured by the deltas.
    """
    def with_repeats(reps):
        stages = tuple(dataclasses.replace(s, repeat=r)
                       for s, r in zip(cfg.stages, reps))
        return dataclasses.replace(cfg, stages=stages, use_scan=False)

    def terms_for(c):
        lowered, chips, _ = _lower_one(c, shape, mesh_kind,
                                       optimizer=optimizer, dtype=dtype)
        return roofline.derive(lowered.compile(), chips)

    base_reps = [1] * len(cfg.stages)
    t0 = terms_for(with_repeats(base_reps))
    flops, byts = t0.flops_per_dev, t0.bytes_per_dev
    breakdown = dict(t0.collective_breakdown)
    for s_idx, st in enumerate(cfg.stages):
        if st.repeat <= 1:
            continue
        reps = list(base_reps)
        reps[s_idx] = 2
        ts = terms_for(with_repeats(reps))
        mult = st.repeat - 1
        flops += mult * max(ts.flops_per_dev - t0.flops_per_dev, 0.0)
        byts += mult * max(ts.bytes_per_dev - t0.bytes_per_dev, 0.0)
        for k in breakdown:
            breakdown[k] += mult * max(
                ts.collective_breakdown.get(k, 0) - t0.collective_breakdown.get(k, 0), 0)
    return roofline.RooflineTerms(
        flops_per_dev=flops, bytes_per_dev=byts,
        collective_bytes_per_dev=float(sum(breakdown.values())),
        collective_breakdown=breakdown, chips=t0.chips)


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            optimizer: str = "drsgda", dtype=jnp.bfloat16,
            scale_analysis: bool = True) -> dict:
    """Lower + compile one combination; returns the result record."""
    shape = INPUT_SHAPES[shape_name]
    cfg = configs.get_config(arch)
    variant = ""
    if shape_name == "long_500k" and configs.needs_long_context_override(cfg):
        cfg = configs.long_context_override(cfg)
        variant = "swa-override"

    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "mode": shape.mode, "optimizer": optimizer,
    }
    t0 = time.time()
    lowered, chips, extra = _lower_one(cfg, shape, mesh_kind,
                                       optimizer=optimizer, dtype=dtype)
    rec.update(extra)
    rec["lower_s"] = round(time.time() - t0, 2)
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)
    rec["chips"] = chips

    # ---- artifacts ---------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = _memory_dict(ma)
    except Exception as e:  # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}

    raw_terms = roofline.derive(compiled, chips)
    rec["roofline_raw"] = raw_terms.as_dict()
    if scale_analysis and any(st.repeat > 1 for st in cfg.stages):
        terms = scaled_roofline_terms(cfg, shape, mesh_kind,
                                      optimizer=optimizer, dtype=dtype)
    else:
        terms = raw_terms
    rec["roofline"] = terms.as_dict()

    n_params = _param_count(cfg, dtype)
    n_active = _active_param_count(cfg)
    rec["n_params"] = n_params
    rec["n_params_active"] = n_active
    if shape.mode == "train":
        mf = roofline.model_flops(n_active, rec["tokens_per_step"])
    else:
        mf = 2.0 * n_active * rec["tokens_per_step"]
    rec["model_flops"] = mf
    hlo_global = terms.flops_per_dev * chips
    rec["useful_fraction"] = roofline.useful_fraction(mf, hlo_global)
    return rec


def _memory_dict(ma) -> dict:
    if ma is None:
        return {"unavailable": True}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(ma, attr):
            try:
                out[attr] = int(getattr(ma, attr))
            except Exception:
                pass
    if not out:
        out["repr"] = str(ma)[:2000]
    return out


def rescale_record(path: str, *, dtype=jnp.bfloat16) -> dict:
    """Patch an existing dry-run record with the differential (scaled)
    roofline — keeps the original full-compile proof/memory stats, demotes
    the unscaled terms to ``roofline_raw``."""
    with open(path) as f:
        rec = json.load(f)
    shape = INPUT_SHAPES[rec["shape"]]
    cfg = configs.get_config(rec["arch"])
    if rec.get("variant") == "swa-override":
        cfg = configs.long_context_override(cfg)
    if "roofline_raw" not in rec:
        rec["roofline_raw"] = rec["roofline"]
    terms = scaled_roofline_terms(cfg, shape, rec["mesh"],
                                  optimizer=rec.get("optimizer", "drsgda"),
                                  dtype=dtype)
    rec["roofline"] = terms.as_dict()
    rec["useful_fraction"] = roofline.useful_fraction(
        rec["model_flops"], terms.flops_per_dev * rec["chips"])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def save_record(rec: dict, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path
