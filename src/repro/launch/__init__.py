from repro.launch import mesh, roofline, steps  # noqa: F401
