"""Step builders: decentralized minimax train_step and serving steps.

``build_trainer`` wires a ModelConfig into the paper's optimizer stack:
LM group-DRO minimax problem (objectives/lm.py) + GossipSpec + DRGDA/DRSGDA
(or a baseline).  ``make_serve_step`` / ``make_prefill_step`` are the
consensus-model inference entry points lowered by the decode/prefill input
shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import OPTIMIZERS
from repro.core.gda import GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.models import transformer as T
from repro.objectives import lm as lm_obj

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """One-object trainer config — the typed alternative to
    ``build_trainer``'s keyword sprawl.

    Every field mirrors the corresponding ``build_trainer`` keyword;
    ``comm`` overrides the config's ``comm_spec()`` when set, and
    ``elastic`` (a ``repro.comms.elastic.ElasticSpec``) switches gossip
    into the elastic execution mode.
    """

    optimizer: str = "drsgda"
    topology: str = "ring"
    mix_backend: Optional[str] = None   # registry name; None => cfg knob
    comm: Any = None                    # CommSpec override; None => cfg
    elastic: Any = None                 # ElasticSpec or None
    telemetry: Any = None               # repro.obs.Telemetry or None
    hyper: Optional[GDAHyper] = None


def build_trainer(cfg: ModelConfig, n_nodes: int,
                  spec: Optional[TrainSpec] = None, *,
                  optimizer: str = "drsgda",
                  hyper: Optional[GDAHyper] = None, topology: str = "ring",
                  dtype=jnp.float32, mesh=None,
                  mix_backend: Optional[str] = None, telemetry=None,
                  elastic=None):
    """Returns (opt, problem).  Default hyper uses k=1 gossip per step (the
    paper's experimental regime); pass k_override=None-in-spec via
    GossipSpec(k_steps=None) + hyper k_override to use the Theorem-1 k.

    Pass a :class:`TrainSpec` as the single ``spec`` argument (the keyword
    form keeps working verbatim; ``spec`` wins when both are given).

    ``mesh`` + ``mix_backend`` (default: the config's ``mix_backend`` knob)
    select how gossip hops execute: given a training mesh whose node axis
    has more than one device, "auto"/"shard_map" route every mix through
    ``repro.comms.backend.ShardMapBackend`` — neighbour-shard ppermute
    exchange instead of stacked roll/einsum mixing.

    ``telemetry`` (a ``repro.obs.Telemetry`` or None) threads wire counters
    through the optimizer state and flushes them via io_callback; None
    compiles the identical pre-obs program.
    """
    from repro.comms.backend import make_backend
    from repro.launch.mesh import gossip_axes

    comm = None
    if spec is not None:
        optimizer, topology = spec.optimizer, spec.topology
        mix_backend, telemetry = spec.mix_backend, spec.telemetry
        comm, elastic, hyper = spec.comm, spec.elastic, spec.hyper

    template = jax.eval_shape(
        lambda k: T.init_params(k, cfg, dtype), jax.random.PRNGKey(0))
    problem = lm_obj.make_lm_problem(cfg, template)
    backend = make_backend(
        mix_backend if mix_backend is not None else cfg.mix_backend,
        mesh=mesh, axis=gossip_axes(mesh) if mesh is not None else "node")
    gossip = GossipSpec(topology=topology, n_nodes=n_nodes, k_steps=1,
                        comm=comm if comm is not None else cfg.comm_spec(),
                        backend=backend, elastic=elastic)
    hyper = hyper or GDAHyper(alpha=0.5, beta=0.02, eta=0.05)
    opt = OPTIMIZERS[optimizer](problem, gossip, hyper, telemetry=telemetry)
    return opt, problem


def init_train_state(key, cfg: ModelConfig, opt, n_nodes: int, batch0,
                     dtype=jnp.float32):
    """Real initialization (smoke tests / the end-to-end driver)."""
    from repro.sharding.partition import project_params_to_manifold

    params = T.init_params(key, cfg, dtype)
    params = project_params_to_manifold(params, opt.problem.manifold_map)
    x0 = broadcast_to_nodes(params, n_nodes)
    y0 = lm_obj.init_y(cfg, n_nodes)
    return opt.init(x0, y0, batch0)


def abstract_train_state(cfg: ModelConfig, opt, n_nodes: int, batch_specs,
                         dtype=jnp.float32):
    """ShapeDtypeStruct state for the dry-run (no allocation)."""
    def build():
        params = T.init_params(jax.random.PRNGKey(0), cfg, dtype)
        x0 = broadcast_to_nodes(params, n_nodes)
        y0 = lm_obj.init_y(cfg, n_nodes)
        return opt.init(x0, y0, batch_specs)
    return jax.eval_shape(build)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def make_serve_step(cfg: ModelConfig, positional_frontend: bool = False):
    """One-token decode against per-layer caches (the ``decode_*`` shapes).

    ``positional_frontend=True`` exposes frontend embeddings as a 5th
    positional argument (pjit + in_shardings does not accept kwargs).
    """
    if positional_frontend:
        def serve_step_fe(params, token, position, cache, frontend_embeds):
            return T.decode_step(params, cfg, token, position, cache,
                                 frontend_embeds=frontend_embeds)
        return serve_step_fe

    def serve_step(params, token, position, cache, frontend_embeds=None):
        logits, new_cache = T.decode_step(params, cfg, token, position, cache,
                                          frontend_embeds=frontend_embeds)
        return logits, new_cache
    return serve_step


def make_prefill_step(cfg: ModelConfig, positional_frontend: bool = False):
    """Full-sequence prefill: final-position logits + populated caches."""
    if positional_frontend:
        def prefill_step_fe(params, tokens, frontend_embeds):
            logits, _, caches = T.forward(params, cfg, tokens,
                                          frontend_embeds=frontend_embeds,
                                          mode="prefill",
                                          last_logits_only=True)
            return logits[:, -1], caches
        return prefill_step_fe

    def prefill_step(params, tokens, frontend_embeds=None):
        logits, _, caches = T.forward(params, cfg, tokens,
                                      frontend_embeds=frontend_embeds,
                                      mode="prefill", last_logits_only=True)
        return logits[:, -1], caches
    return prefill_step
