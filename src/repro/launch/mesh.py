"""Mesh construction.

``make_production_mesh`` is the canonical grid required by the dry-run spec:
(16, 16) ("data", "model") per pod, (2, 16, 16) ("pod", "data", "model") for
two pods.  ``make_training_mesh`` refines the same 256-chip-per-pod grid
into the 4-axis logical mesh the decentralized optimizer uses
("pod", "node", "fsdp", "model") — node x fsdp x model == 256, factorization
chosen per architecture (MeshPlan).  All constructors are FUNCTIONS so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import MeshPlan

CHIPS_PER_POD = 256
PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (PODS, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_training_mesh(plan: MeshPlan, *, multi_pod: bool = False,
                       devices=None) -> Mesh:
    """Refine the production grid into ("pod","node","fsdp","model").

    Uses the same device ordering as make_production_mesh (row-major over
    the per-pod 256-chip grid) so the physical ICI neighbourhoods match.
    """
    n_pods = PODS if multi_pod else 1
    if devices is None:
        devices = np.asarray(jax.devices()[: n_pods * CHIPS_PER_POD])
    else:
        devices = np.asarray(devices)
    grid = devices.reshape(n_pods, plan.node, plan.fsdp, plan.model)
    if multi_pod:
        return Mesh(grid, ("pod", "node", "fsdp", "model"))
    return Mesh(grid[0], ("node", "fsdp", "model"))


def make_serving_mesh(*, multi_pod: bool = False) -> Mesh:
    return make_production_mesh(multi_pod=multi_pod)


def make_host_mesh(node: int = 1, fsdp: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over however many (host) devices exist — used by tests."""
    n = node * fsdp * model
    devices = np.asarray(jax.devices()[:n]).reshape(node, fsdp, model)
    return Mesh(devices, ("node", "fsdp", "model"))


def total_nodes(plan: MeshPlan, multi_pod: bool) -> int:
    return plan.node * (PODS if multi_pod else 1)


def gossip_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes the decentralized node dimension lives on.  Multi-pod
    meshes extend the gossip ring across pods: ("pod", "node")."""
    return ("pod", "node") if "pod" in mesh.shape else ("node",)
