import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS")
    or "--xla_force_host_platform_device_count=512")

# --- everything below runs with the placeholder device grid ---------------
import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

from repro import configs                      # noqa: E402
from repro.launch import dryrun_lib            # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower+compile every "
                    "(arch x shape x mesh) with ShapeDtypeStruct inputs.")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    choices=["all", *configs.base.INPUT_SHAPES])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="drsgda")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    ap.add_argument("--no-scale", action="store_true",
                    help="skip the differential (scan-aware) roofline "
                         "scaling (multi-pod proof runs)")
    ap.add_argument("--rescale-existing", action="store_true",
                    help="patch existing records with the differential "
                         "(scan-aware) roofline instead of recompiling")
    args = ap.parse_args(argv)

    if args.rescale_existing:
        import glob
        failures = 0
        for path in sorted(glob.glob(os.path.join(args.out, "*.json"))):
            t0 = time.time()
            with open(path) as f:
                peek = json.load(f)
            if peek.get("mesh") != "single" or "roofline_raw" in peek:
                continue  # roofline table is single-pod; already-scaled skip
            try:
                rec = dryrun_lib.rescale_record(path)
                r = rec["roofline"]
                print(f"[rescaled] {os.path.basename(path)} "
                      f"({time.time()-t0:.1f}s) dominant={r['dominant']} "
                      f"compute={r['compute_s']:.3e}s "
                      f"memory={r['memory_s']:.3e}s "
                      f"collective={r['collective_s']:.3e}s", flush=True)
            except Exception as e:
                failures += 1
                print(f"[FAIL] {path}: {type(e).__name__}: {e}", flush=True)
                if args.fail_fast:
                    raise
        return 1 if failures else 0

    archs = list(configs.ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(configs.base.INPUT_SHAPES) if args.shape == "all" \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                tag = f"{arch} x {shape} x {mesh}"
                t0 = time.time()
                try:
                    rec = dryrun_lib.run_one(arch, shape, mesh,
                                             optimizer=args.optimizer,
                                             scale_analysis=not args.no_scale)
                    path = dryrun_lib.save_record(rec, args.out)
                    r = rec["roofline"]
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"dominant={r['dominant']} "
                          f"compute={r['compute_s']:.3e}s "
                          f"memory={r['memory_s']:.3e}s "
                          f"collective={r['collective_s']:.3e}s -> {path}",
                          flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {tag} after {time.time()-t0:.1f}s: "
                          f"{type(e).__name__}: {e}", flush=True)
                    if args.fail_fast:
                        raise
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
