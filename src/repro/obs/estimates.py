"""Analytical per-kernel cost estimates + per-call aggregation.

tinygrad-style accounting (SNIPPETS.md §Estimates): every kernel call is
described by an :class:`Estimates` triple

  ``ops`` — floating-point operations,
  ``lds`` — bytes moved through loads and stores (revisits counted, i.e.
            what the memory system actually serves),
  ``mem`` — unique bytes touched (the lower bound an ideal cache achieves),

derived *analytically from shapes*, never from profiling — so the numbers
are available on any backend (including this CPU container) and feed
``launch/roofline.py`` real per-kernel inputs instead of only HLO parsing.

``kernels/ops.py``'s dispatch wrappers record one estimate per call into the
module-level :data:`GLOBAL` counters (and any :func:`collect` scopes on the
stack).  Under ``jit`` the Python wrapper runs at **trace time**, so counts
are per-traced-call: a kernel traced once inside a step that executes T
times contributes its estimate once — multiply by executed steps (what
``benchmarks/obs.py`` does) for run totals.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading


@dataclasses.dataclass(frozen=True)
class Estimates:
    """Analytical cost of one kernel call."""
    ops: float = 0.0   # floating-point operations
    lds: float = 0.0   # bytes served by loads + stores (revisits counted)
    mem: float = 0.0   # unique bytes touched

    def __add__(self, o: "Estimates") -> "Estimates":
        return Estimates(self.ops + o.ops, self.lds + o.lds, self.mem + o.mem)

    def scaled(self, k: float) -> "Estimates":
        return Estimates(self.ops * k, self.lds * k, self.mem * k)

    @property
    def intensity(self) -> float:
        """Arithmetic intensity in FLOP/byte (ops over unique bytes)."""
        return self.ops / max(self.mem, 1.0)

    def as_dict(self) -> dict:
        return {"ops": self.ops, "lds": self.lds, "mem": self.mem,
                "intensity": self.intensity}


# ---------------------------------------------------------------------------
# per-kernel analytical models (shapes in, Estimates out)
# ---------------------------------------------------------------------------


def flash_attention_est(b: int, s: int, t: int, h: int, hd: int, *,
                        causal: bool = True, window: int | None = None,
                        block_q: int = 128, itemsize: int = 4) -> Estimates:
    """Blockwise attention over (B, H, S, hd) x (B, H, T, hd).

    Two matmuls (QK^T and PV) at 2*s*t*hd each plus ~5 flop/score for the
    online softmax; a causal square mask halves the visited score area, a
    sliding window caps each query's keys at ``window``.
    """
    t_eff = float(min(t, window)) if window else float(t)
    frac = 0.5 if (causal and s == t and not window) else 1.0
    scores = b * h * s * t_eff * frac
    ops = scores * (4.0 * hd + 5.0)
    # q streamed once; k/v re-read once per q block (the flash loop)
    q_blocks = max(1, -(-s // max(block_q, 1)))
    lds = itemsize * b * h * (2.0 * s * hd + 2.0 * q_blocks * t * hd * frac)
    mem = itemsize * b * h * (2.0 * s * hd + 2.0 * t * hd)
    return Estimates(ops=ops, lds=lds, mem=mem)


def paged_decode_est(s: int, h: int, hkv: int, hd: int, m_pages: int,
                     page_size: int, *, itemsize: int = 4) -> Estimates:
    """Paged-decode attention: one query token per slot over ``m_pages``
    block-table pages of ``page_size`` tokens.  Same 4*hd+5 flop/score core
    as flash attention; k/v pages stream once per (slot, kv-head) pair (the
    grid revisits the pool per kv head), q/o are one token per slot."""
    t = float(m_pages) * page_size
    scores = float(s) * h * t
    ops = scores * (4.0 * hd + 5.0)
    qo = 2.0 * s * h * hd
    kv = 2.0 * s * hkv * t * hd
    lds = itemsize * (qo + kv)
    mem = itemsize * (qo + kv)      # pages are slot-private (no sharing)
    return Estimates(ops=ops, lds=lds, mem=mem)


def stiefel_project_est(d: int, r: int, *, lead: int = 1,
                        itemsize: int = 4) -> Estimates:
    """P_{T_x}(g) = g - x sym(x^T g): two d x r x r matmuls + r^2 sym."""
    ops = lead * (4.0 * d * r * r + 2.0 * r * r + d * r)
    lds = itemsize * lead * (4.0 * d * r)      # x read twice, g once, out once
    mem = itemsize * lead * (3.0 * d * r)
    return Estimates(ops=ops, lds=lds, mem=mem)


def fused_retract_est(d: int, r: int, *, ns_iters: int = 20, lead: int = 1,
                      itemsize: int = 4) -> Estimates:
    """Fused polar retraction: tangent project + Gram + Newton-Schulz
    inverse-sqrt (r x r, ``ns_iters`` iterations at ~2 matmuls each) + apply,
    in one two-pass VMEM-resident kernel."""
    grams = 6.0 * d * r * r              # x^T x, x^T g, cross terms (pass 1)
    ns = ns_iters * 4.0 * r ** 3         # two r x r matmuls per NS iteration
    apply = 2.0 * d * r * r + 4.0 * d * r   # (x + u) @ invsqrt + u assembly
    ops = lead * (grams + ns + apply)
    # two passes over both d x r operands + one output write
    lds = itemsize * lead * (4.0 * d * r + d * r)
    mem = itemsize * lead * (3.0 * d * r)
    return Estimates(ops=ops, lds=lds, mem=mem)


def ring_mix_est(n_elems: int, *, itemsize: int = 4) -> Estimates:
    """wc*x + ws*(l + r): 4 flop/element over three inputs, one output."""
    return Estimates(ops=4.0 * n_elems,
                     lds=itemsize * 4.0 * n_elems,
                     mem=itemsize * 4.0 * n_elems)


def quant_mix_est(rows: int, cols: int, *, out_itemsize: int = 4) -> Estimates:
    """Fused dequantize + 3-way combine: 3 dequant muls + 4 combine flops per
    element; loads are int8 payloads + one f32 scale per row."""
    n = float(rows) * cols
    ops = 7.0 * n
    lds = 3.0 * n + 3.0 * 4.0 * rows + out_itemsize * n
    mem = lds
    return Estimates(ops=ops, lds=lds, mem=mem)


def multi_hop_mix_est(rows: int, f: int, *, hops: int, out_rows: int,
                      itemsize: int = 4, quant: bool = False) -> Estimates:
    """Fused k-hop halo-panel megakernel.

    fp32: one panel read, ``hops`` combines at 4 flop/element in VMEM, one
    ``(out_rows, f)`` write — the unfused schedule's 2k HBM round trips
    collapse to ~1.  int8 all-hop: the payload arrives as 1 byte/element
    (+4 B/row scales), hop 0 adds 1 dequant mul/element, later hops add a
    ~4 flop/element requant (div, round, clip, mul) and revisit the f32
    state panel once per stage (max pass + combine pass)."""
    n = float(rows) * f
    ops = 4.0 * hops * n
    if quant:
        ops += n + 4.0 * max(hops - 1, 0) * n       # dequant + requants
        in_bytes = 1.0 * n + 4.0 * rows
        # state panel written at every combine stage, re-read at every
        # max + requant stage (the revisiting-grid traffic)
        lds = in_bytes + 4.0 * n * (3.0 * max(hops - 1, 0) + 1.0)
        mem = in_bytes + 4.0 * n
    else:
        in_bytes = float(itemsize) * n
        lds = in_bytes + float(itemsize) * out_rows * f
        mem = lds
    return Estimates(ops=ops, lds=lds, mem=mem)


#: the registered estimators, keyed by the ops.py dispatch name
KERNELS = {
    "flash_attention": flash_attention_est,
    "paged_decode": paged_decode_est,
    "stiefel_project": stiefel_project_est,
    "fused_retract": fused_retract_est,
    "ring_mix": ring_mix_est,
    "quant_mix": quant_mix_est,
    "multi_hop_mix": multi_hop_mix_est,
    "multi_hop_mix_quant": functools.partial(multi_hop_mix_est, quant=True),
}


# ---------------------------------------------------------------------------
# per-call aggregation
# ---------------------------------------------------------------------------


class KernelCounters:
    """Aggregates (calls, Estimates) per kernel name."""

    def __init__(self):
        self._lock = threading.Lock()
        self.records: dict[str, dict] = {}

    def record(self, name: str, est: Estimates) -> None:
        with self._lock:
            rec = self.records.setdefault(
                name, {"calls": 0, "est": Estimates()})
            rec["calls"] += 1
            rec["est"] = rec["est"] + est

    def snapshot(self) -> dict:
        """JSON-able {kernel: {calls, ops, lds, mem, intensity}}."""
        with self._lock:
            return {name: {"calls": rec["calls"], **rec["est"].as_dict()}
                    for name, rec in sorted(self.records.items())}

    def reset(self) -> None:
        with self._lock:
            self.records.clear()

    @property
    def total(self) -> Estimates:
        with self._lock:
            out = Estimates()
            for rec in self.records.values():
                out = out + rec["est"]
            return out


#: always-on global counters (reset() between benchmark phases)
GLOBAL = KernelCounters()

_STACK: list[KernelCounters] = []


def record(name: str, est: Estimates) -> None:
    """Record one kernel call into GLOBAL and every active collect() scope."""
    GLOBAL.record(name, est)
    for c in _STACK:
        c.record(name, est)


@contextlib.contextmanager
def collect():
    """Scoped collector: ``with collect() as c: ...; c.snapshot()``."""
    c = KernelCounters()
    _STACK.append(c)
    try:
        yield c
    finally:
        _STACK.remove(c)
