"""Host-side spans + Chrome-trace / Perfetto export.

``Trace`` records wall-clock *complete* events ("ph": "X") from the
``span()`` context manager (nesting is reconstructed by Perfetto from the
timestamps), counter tracks ("ph": "C") from flushed jit counters, and
instants.  ``to_chrome_trace()`` emits the standard
``{"traceEvents": [...]}`` JSON that both ``chrome://tracing`` and
https://ui.perfetto.dev open directly; ``from_chrome_trace`` round-trips it
(schema-checked by ``tests/test_obs.py``).

Timestamps are microseconds since the trace epoch (``t0``), per the trace
event format.  Spans are cheap (one ``perf_counter`` pair + a dict append)
— they wrap *host* boundaries (a jitted step call, an eval pass, a
benchmark phase), never code inside a jit trace; in-jit accounting is
``obs.wire``'s job.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Optional


class Trace:
    """An in-memory Chrome-trace event buffer for one run."""

    def __init__(self, run: str = "run", pid: int = 0):
        self.run = run
        self.pid = pid
        self.t0 = time.perf_counter()
        self.events: list[dict] = []
        self._lock = threading.Lock()

    # -- clock --------------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter() - self.t0) * 1e6

    def _tid(self) -> int:
        return threading.get_ident() % 1_000_000

    def _append(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    # -- recording ----------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "host", **args: Any):
        """Wall-clock region: ``with trace.span("step", step=t): ...``."""
        t0 = self._now_us()
        try:
            yield self
        finally:
            t1 = self._now_us()
            self._append({"name": name, "cat": cat, "ph": "X", "ts": t0,
                          "dur": t1 - t0, "pid": self.pid, "tid": self._tid(),
                          "args": args})

    def instant(self, name: str, cat: str = "host", **args: Any) -> None:
        self._append({"name": name, "cat": cat, "ph": "i", "s": "g",
                      "ts": self._now_us(), "pid": self.pid,
                      "tid": self._tid(), "args": args})

    def counter(self, name: str, values: dict[str, float],
                ts: Optional[float] = None) -> None:
        """Counter track (one series per dict key)."""
        self._append({"name": name, "cat": "counters", "ph": "C",
                      "ts": self._now_us() if ts is None else ts,
                      "pid": self.pid,
                      "args": {k: float(v) for k, v in values.items()}})

    # -- export -------------------------------------------------------------

    def spans(self) -> list[dict]:
        return [e for e in self.events if e["ph"] == "X"]

    def to_chrome_trace(self) -> dict:
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {"run": self.run, "format": "repro.obs/chrome-trace"},
        }

    def save(self, path: str) -> str:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path

    @classmethod
    def from_chrome_trace(cls, payload: dict) -> "Trace":
        """Inverse of :meth:`to_chrome_trace` (round-trip tested)."""
        other = payload.get("otherData", {})
        t = cls(run=other.get("run", "run"))
        t.events = list(payload["traceEvents"])
        return t

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_chrome_trace(json.load(f))
