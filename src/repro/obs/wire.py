"""jit-safe wire counters, threaded as an optimizer-state leaf.

``WireCounters`` is a NamedTuple of scalars that rides through the jitted
step exactly like ``CommState`` does: the optimizers thread it via
:func:`wrap_mixer`, which intercepts every ``mix(slot, tree, steps)`` call
and accumulates

* static accounting — bytes per hop from the backend's ``est_hop_bytes``
  (the same oracle ``benchmarks/mix_backend.py`` reports, so counter-derived
  bytes/hop and the bench's estimates agree by construction) and, under a
  ``CommEngine``, the compressed-round bytes from
  ``CommEngine.wire_round_bytes`` (payload fan-out + exact hat hops);
* dynamic accounting — per-hop link activity under a non-trivial
  ``ChannelModel``: the same ``W_t`` draws the mix consumes are re-derived
  from the engine's key schedule (``CommEngine.chan_key``) and reduced to
  scheduled/active link counts (``ChannelModel.link_stats``), so dropped
  links and the effective wire bytes are *traced* values.

Counters never feed back into the update math — a trajectory with obs on is
bit-identical to obs off (test-enforced).  The threaded leaf is one packed
``f32[6]`` vector, not six scalar leaves: a single extra jit argument /
output / donated buffer and one fused vector-add per mix call keeps the
per-step dispatch overhead near zero.  :class:`WireCounters` is the
host-side unpacked view (:func:`unpack`).  Everything accumulates in f32 —
counts stay exact below 2**24 (ample for the covered run lengths); flush
windows reset nothing, the counters are cumulative and readers difference
consecutive flushes.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


class WireCounters(NamedTuple):
    """Host-side view of the packed counter vector (see :func:`unpack`)."""
    rounds: Any            # int — mix() calls (one slot, any number of hops)
    hops: Any              # int — gossip hops executed
    wire_bytes: Any        # float — bytes actually put on the wire
    raw_bytes: Any         # float — bytes a full-precision exchange would move
    active_links: Any      # float — (link, hop) pairs that carried payload
    dropped_links: Any     # float — scheduled (link, hop) pairs lost to faults

    def as_dict(self) -> dict:
        return {k: v for k, v in zip(self._fields, self)}


N_COUNTERS = len(WireCounters._fields)
_INT_FIELDS = ("rounds", "hops")


def zero_counters() -> Array:
    """The packed ``f32[6]`` counter leaf (one buffer — donation-friendly)."""
    return jnp.zeros((N_COUNTERS,), jnp.float32)


def unpack(counters) -> WireCounters:
    """Packed vector (device array or numpy) -> typed host view."""
    vals = np.asarray(counters)
    return WireCounters(*(
        int(v) if f in _INT_FIELDS else float(v)
        for f, v in zip(WireCounters._fields, vals)))


def static_link_count(spec) -> float:
    """Undirected edges of the topology graph (off-diagonal support of W)."""
    w = np.asarray(spec.matrix)
    off = (w - np.diag(np.diag(w))) > 0
    return float(np.count_nonzero(off)) / 2.0


def account_mix(counters: Array, gossip, engine, backend,
                comm_state, slot: str, tree: PyTree, steps: int,
                rnd) -> Array:
    """Packed counters after one ``mix(slot, tree, steps)`` call."""
    if gossip.n_nodes == 1 or steps == 0:
        return counters
    n_links = static_link_count(gossip)
    sched = float(steps) * n_links
    per_hop = backend.est_hop_bytes(gossip, tree)
    raw = float(steps) * per_hop

    if engine is None:
        wire: Array | float = raw
        active: Array | float = sched
        dropped: Array | float = 0.0
    elif getattr(engine, "elastic", None) is not None:
        # elastic execution mode: only live links carry payload.  Re-derive
        # the round's realized link mask from the same RoundView the mix
        # consumed (identical key schedule), count live-scheduled vs
        # realized pairs, and scale the wire estimate by the realized
        # fraction of the static graph.
        wire, raw = engine.wire_round_bytes(tree, steps)
        sched_live, act = engine.link_stats(comm_state, slot, rnd)
        sched_live = sched_live * float(steps)
        act = act * float(steps)
        wire = wire * act / jnp.maximum(float(steps) * n_links, 1.0)
        active, dropped = act, sched_live - act
    else:
        wire, raw = engine.wire_round_bytes(tree, steps)
        if engine.channel.trivial:
            active, dropped = sched, 0.0
        else:
            k_chan = engine.chan_key(comm_state, slot, rnd)
            sched_t = jnp.zeros((), jnp.float32)
            act_t = jnp.zeros((), jnp.float32)
            for h in range(steps):
                s_h, a_h = engine.channel.link_stats(
                    rnd * steps + h, jax.random.fold_in(k_chan, h))
                sched_t += s_h
                act_t += a_h
            # faulty links carry nothing: scale the wire estimate by the
            # realized active-link fraction (first-order, uniform links)
            wire = wire * act_t / jnp.maximum(sched_t, 1.0)
            active, dropped = act_t, sched_t - act_t

    # one fused vector-add per mix call (order = WireCounters._fields)
    delta = jnp.stack([jnp.float32(1.0), jnp.float32(steps),
                       jnp.float32(wire), jnp.float32(raw),
                       jnp.float32(active), jnp.float32(dropped)])
    return counters + delta


def wrap_mixer(mix: Callable[[str, PyTree, int], PyTree],
               counters: Optional[Array], gossip, engine, backend,
               comm_state, rnd
               ) -> tuple[Callable[[str, PyTree, int], PyTree],
                          Callable[[], Optional[Array]]]:
    """Instrument a ``make_mixer`` mix function with wire accounting.

    Returns ``(mix2, counters_final)``; with ``counters is None`` the mix is
    returned untouched (telemetry off costs nothing).
    """
    if counters is None:
        return mix, lambda: None
    box = {"c": counters}

    def mix2(slot: str, tree: PyTree, steps: int) -> PyTree:
        out = mix(slot, tree, steps)
        box["c"] = account_mix(box["c"], gossip, engine, backend,
                               comm_state, slot, tree, steps, rnd)
        return out

    return mix2, lambda: box["c"]
