"""repro.obs — jit-safe telemetry: spans + traces, wire/kernel counters,
and the streaming convergence dashboard.

Three coordinated layers (see each module's docstring):

* :mod:`repro.obs.trace` — host-side spans, Chrome-trace/Perfetto export;
* :mod:`repro.obs.wire` + :mod:`repro.obs.estimates` — jit-threaded wire
  counters and analytical per-kernel cost estimates;
* :mod:`repro.obs.telemetry` — the ``Telemetry`` facade the optimizers,
  ``launch/train.py`` and ``benchmarks/obs.py`` consume, flushing to the
  schema-validated JSONL event log (:mod:`repro.obs.events`).

This package never imports ``repro.core`` or ``repro.kernels`` at module
scope (the dependency points the other way), so it can sit underneath both.
"""
from repro.obs import estimates, events, trace, wire  # noqa: F401
from repro.obs.estimates import Estimates  # noqa: F401
from repro.obs.telemetry import Telemetry  # noqa: F401
from repro.obs.trace import Trace  # noqa: F401
from repro.obs.wire import (WireCounters, unpack, wrap_mixer,  # noqa: F401
                            zero_counters)
