"""The user-facing telemetry facade.

One ``Telemetry`` object per run ties the three layers together:

* **spans / trace** — a host-side :class:`repro.obs.trace.Trace` whose
  Chrome-trace JSON lands under ``out_dir`` at :meth:`export`;
* **jit counters** — :meth:`init_counters` seeds the packed ``f32[6]``
  counter leaf (``WireCounters`` is its host-side view) and
  :meth:`flush_counters` emits it from *inside* a
  jitted step via ``jax.experimental.io_callback``.  Any program containing
  an io_callback pays a per-call host tax (effects disable the fast
  dispatch path), so the optimizers' ``make_step`` compiles TWO
  executables from the same step function via :meth:`flush_mode`: a quiet
  effect-free one for ordinary steps and a flushing one used every
  ``flush_every``-th call — both fully fused, and the tax lands on one
  call per flush window.  The default ``"cond"`` mode (a ``lax.cond`` on
  ``step % flush_every == 0``) keeps standalone ``opt.step`` jits correct
  without the dual-executable wrapper;
* **event log** — every flush / dashboard / export appends a
  schema-validated line to ``<out_dir>/<run>.events.jsonl``.

The object is static configuration: it is captured by the jitted closure
(like ``CommEngine``), never traced.  Passing ``telemetry=None`` (the
default everywhere) compiles the exact same program as before this module
existed.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Any, Optional

import jax
from jax.experimental import io_callback

from repro.obs import events as obs_events
from repro.obs import wire as obs_wire
from repro.obs.trace import Trace

PyTree = Any


@dataclasses.dataclass
class Telemetry:
    """Static per-run telemetry configuration + host-side sinks."""

    run: str = "run"
    out_dir: str = os.path.join("experiments", "telemetry")
    flush_every: int = 50          # io_callback cadence, in optimizer steps
    enabled: bool = True
    trace: Trace = None            # created in __post_init__ when omitted

    def __post_init__(self):
        if self.trace is None:
            self.trace = Trace(run=self.run)
        self._meta_written = False
        self._flush_mode = "cond"

    # -- paths --------------------------------------------------------------

    @property
    def events_path(self) -> str:
        return os.path.join(self.out_dir, f"{self.run}.events.jsonl")

    @property
    def trace_path(self) -> str:
        return os.path.join(self.out_dir, f"{self.run}.trace.json")

    # -- host-side event emission -------------------------------------------

    def event(self, type_: str, data: dict, step: Optional[int] = None) -> dict:
        self._ensure_meta()
        ev = obs_events.make_event(type_, self.run, data, step=step)
        obs_events.append_jsonl(self.events_path, ev)
        return ev

    def _ensure_meta(self) -> None:
        if self._meta_written:
            return
        self._meta_written = True
        ev = obs_events.make_event(
            "meta", self.run,
            {"backend": jax.default_backend(),
             "device_count": jax.device_count(),
             "flush_every": self.flush_every,
             "started": time.strftime("%Y-%m-%dT%H:%M:%S")})
        obs_events.append_jsonl(self.events_path, ev)

    def span(self, name: str, **args):
        return self.trace.span(name, **args)

    # -- jit-side counters --------------------------------------------------

    def init_counters(self) -> jax.Array:
        return obs_wire.zero_counters()

    def _flush_cb(self, step, vals) -> None:
        # runs on the host; never let telemetry kill a training step
        try:
            data = obs_wire.unpack(vals).as_dict()
            self.event("counters", data, step=int(step))
            self.trace.counter("wire", {"wire_bytes": data["wire_bytes"],
                                        "raw_bytes": data["raw_bytes"],
                                        "hops": data["hops"]})
        except Exception as e:     # pragma: no cover - defensive
            print(f"[obs] counter flush failed: {e!r}", flush=True)

    @contextlib.contextmanager
    def flush_mode(self, mode: str):
        """Trace-time switch for :meth:`flush_counters`: ``"cond"`` (default,
        runtime step check), ``"always"`` (unconditional io_callback — the
        flush executable), ``"never"`` (no effects at all — the quiet
        executable, whose program is free of the effect dispatch tax)."""
        assert mode in ("cond", "always", "never"), mode
        prev = self._flush_mode
        self._flush_mode = mode
        try:
            yield self
        finally:
            self._flush_mode = prev

    def flush_counters(self, counters: Optional[jax.Array], step) -> None:
        """Call inside the jitted step: host flush every ``flush_every``
        steps (unordered io_callback — steps stay fused; see
        :meth:`flush_mode` for how make_step keeps quiet steps effect-free).
        ``counters`` is the packed ``f32[6]`` leaf from
        :meth:`init_counters`.
        """
        if counters is None or self._flush_mode == "never":
            return
        if self._flush_mode == "always":
            io_callback(self._flush_cb, None, step, counters, ordered=False)
            return

        def do(args):
            io_callback(self._flush_cb, None, *args, ordered=False)
            return 0

        jax.lax.cond(step % self.flush_every == 0, do, lambda args: 0,
                     (step, counters))

    # -- convergence dashboard ----------------------------------------------

    def dashboard(self, problem, x_stacked: PyTree, y_stacked, batches,
                  step: int, extra: Optional[dict] = None) -> dict:
        """Stream M_t components + per-geometry feasibility + cross-node
        drift into the event log (host-side, at ``eval_every`` cadence)."""
        from repro.core import metric as core_metric  # lazy: no import cycle

        m = core_metric.convergence_metric(problem, x_stacked, y_stacked,
                                           batches)
        data = {k: float(v) for k, v in m.items()}
        data["drift"] = {
            name: float(v)
            for name, v in core_metric.per_leaf_drift(
                problem, x_stacked).items()}
        if extra:
            data.update(extra)
        return self.event("dashboard", data, step=step)

    # -- export -------------------------------------------------------------

    def export(self) -> dict:
        """Write the Perfetto/Chrome trace; returns the artifact paths."""
        self._ensure_meta()
        path = self.trace.save(self.trace_path)
        return {"trace": path, "events": self.events_path}


def read_counter_series(events_path: str) -> list[dict]:
    """The flushed counter events of a run, in step order."""
    rows = [ev for ev in obs_events.read_jsonl(events_path)
            if ev["type"] == "counters"]
    rows.sort(key=lambda ev: ev.get("step", 0))
    return rows


def latest_dashboard(events_path: str) -> Optional[dict]:
    rows = [ev for ev in obs_events.read_jsonl(events_path)
            if ev["type"] == "dashboard"]
    return max(rows, key=lambda ev: ev.get("step", 0)) if rows else None


def summarize_run(events_path: str) -> dict:
    """Compact JSON summary of one event log (used by build_report)."""
    counters = read_counter_series(events_path)
    dash = latest_dashboard(events_path)
    out: dict = {"n_events": obs_events.validate_log(events_path)}
    if counters:
        last = counters[-1]
        out["counters"] = last["data"]
        out["counters_step"] = last.get("step")
        hops = max(last["data"].get("hops", 0), 1)
        out["bytes_per_hop"] = last["data"].get("wire_bytes", 0.0) / hops
    if dash:
        out["dashboard"] = dash["data"]
        out["dashboard_step"] = dash.get("step")
    return out
