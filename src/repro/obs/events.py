"""Append-only JSONL event log + checked-in schema validation.

Every telemetry artifact funnels through one record shape (see
``event_schema.json`` next to this module): ``{type, ts, run, data[, step]}``.
The validator implements the JSON-Schema subset the checked-in schema uses
(type / required / properties / enum / additionalProperties) so validation
needs no third-party dependency and runs in CI against every emitted line.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Iterator, Optional

SCHEMA_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "event_schema.json")

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
}


def load_schema() -> dict:
    with open(SCHEMA_PATH) as f:
        return json.load(f)


_SCHEMA_CACHE: Optional[dict] = None


def schema() -> dict:
    global _SCHEMA_CACHE
    if _SCHEMA_CACHE is None:
        _SCHEMA_CACHE = load_schema()
    return _SCHEMA_CACHE


def _check(value: Any, spec: dict, path: str) -> None:
    t = spec.get("type")
    if t == "number":
        if isinstance(value, bool) or not isinstance(value, (int, float)):
            raise ValueError(f"{path}: expected number, got {type(value).__name__}")
    elif t == "integer":
        if isinstance(value, bool) or not isinstance(value, int):
            raise ValueError(f"{path}: expected integer, got {type(value).__name__}")
    elif t in _TYPES:
        if not isinstance(value, _TYPES[t]):
            raise ValueError(f"{path}: expected {t}, got {type(value).__name__}")
    if "enum" in spec and value not in spec["enum"]:
        raise ValueError(f"{path}: {value!r} not in {spec['enum']}")
    if t == "object" and isinstance(value, dict):
        props = spec.get("properties", {})
        for req in spec.get("required", ()):
            if req not in value:
                raise ValueError(f"{path}: missing required field {req!r}")
        if spec.get("additionalProperties") is False:
            extra = set(value) - set(props)
            if extra:
                raise ValueError(f"{path}: unexpected fields {sorted(extra)}")
        for name, sub in props.items():
            if name in value:
                _check(value[name], sub, f"{path}.{name}")


def validate_event(ev: dict, sch: Optional[dict] = None) -> dict:
    """Raise ValueError if ``ev`` does not conform; return ``ev``."""
    _check(ev, sch if sch is not None else schema(), "$")
    return ev


def make_event(type_: str, run: str, data: dict,
               step: Optional[int] = None, ts: Optional[float] = None) -> dict:
    ev: dict = {"type": type_, "ts": time.time() if ts is None else ts,
                "run": run, "data": data}
    if step is not None:
        ev["step"] = int(step)
    return validate_event(ev)


def append_jsonl(path: str, ev: dict) -> None:
    """Validate and append one event line (creates parent dirs)."""
    validate_event(ev)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(ev) + "\n")


def read_jsonl(path: str) -> Iterator[dict]:
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                yield json.loads(line)


def validate_log(path: str) -> int:
    """Validate every line of a JSONL event log; returns the event count."""
    n = 0
    sch = schema()
    for ev in read_jsonl(path):
        validate_event(ev, sch)
        n += 1
    return n
