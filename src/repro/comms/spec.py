"""Static description of the communication layer (compression + channel).

Kept free of jax/core imports so ``core.gossip`` (and the pure-dataclass
config schema) can reference it without an import cycle: ``CommSpec`` is the
value carried by ``GossipSpec.comm`` and by the ``comm_*`` knobs on
``ModelConfig``.  All runtime machinery lives in :mod:`repro.comms.compress`,
:mod:`repro.comms.channel` and :mod:`repro.comms.layer`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

CompressorKind = Literal["none", "int8", "topk", "lowrank"]
Schedule = Literal["static", "round_robin", "matching"]


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Everything between the optimizer and the wire, as static config.

    Compression (CHOCO-style): each node keeps a public copy ``x_hat`` of its
    state; one gossip round transmits ``C(x - x_hat)``, every replica folds
    the payload into its hats, and consensus steps on the hats with step size
    ``gamma``.  ``error_feedback=False`` drops the memory (naive quantized
    gossip — plateaus at the compressor's noise floor, kept for ablation).

    Channel: one gossip hop may be perturbed by seeded i.i.d. link drops,
    straggler skips (a straggling node neither sends nor receives), and a
    time-varying edge schedule.  Dropped weight folds back into the diagonal
    so every effective ``W_t`` stays symmetric doubly stochastic.
    """
    # --- compression -------------------------------------------------------
    compressor: CompressorKind = "none"
    topk_frac: float = 0.05        # fraction of entries kept per node (topk)
    rank: int = 4                  # retained rank per matrix leaf (lowrank)
    error_feedback: bool = True    # CHOCO memory on/off
    gamma: float = 0.9             # consensus step size on the hats
    # "fixed" uses the ``gamma`` constant; "adaptive" tracks the compressor's
    # empirical contraction delta (EMA, per slot, in CommState.deltas) and
    # steps with it — see CommEngine._gamma.
    gamma_mode: Literal["fixed", "adaptive"] = "fixed"
    gamma_ema: float = 0.9         # EMA smoothing of the observed delta
    gamma_min: float = 0.05        # floor on the adaptive step
    fuse_kernel: bool = True       # int8 ring hop through the quant_mix kernel
    # which hops of a multi-hop (k > 1) fused int8 round are compressed:
    # "first" ships C(x - x_hat) once then mixes the hats in fp32 (the
    # original CHOCO wire), "all" deterministically requantizes at EVERY hop
    # so int8 bytes are all that ever travel (multi_hop_mix_quant megakernel
    # under the shard_map backend)
    quant_hops: Literal["first", "all"] = "first"
    # --- channel -----------------------------------------------------------
    drop_rate: float = 0.0         # per-edge i.i.d. Bernoulli drop probability
    straggler_rate: float = 0.0    # per-node i.i.d. skip probability
    schedule: Schedule = "static"  # edge activation schedule per round
    seed: int = 0                  # base PRNG seed for quantization + channel

    @property
    def compressed(self) -> bool:
        return self.compressor != "none"

    @property
    def adaptive_gamma(self) -> bool:
        return self.gamma_mode == "adaptive"

    @property
    def channel_active(self) -> bool:
        return (self.drop_rate > 0.0 or self.straggler_rate > 0.0
                or self.schedule != "static")

    @property
    def enabled(self) -> bool:
        return self.compressed or self.channel_active
