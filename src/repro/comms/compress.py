"""Compression operators for gossip payloads.

Every compressor maps a node-stacked leaf ``x`` (axis 0 = node) to the
reconstruction its receivers would decode, plus a *static* account of the
bits that crossed the wire.  Keeping the bit accounting static (pure Python
over shapes) means the benchmark's bits-per-parameter sweep costs nothing
inside jit.

Operators:

* ``identity``  — lossless, 32 bits/entry baseline.
* ``int8``      — per-node max-abs scale + unbiased stochastic rounding to
  int8 (the payload the ``quant_mix`` Pallas kernel consumes).
* ``topk``      — per-node magnitude top-k sparsification (value + index).
* ``lowrank``   — randomized rank-p sketch ``Q (Q^T A)`` for matrix leaves
  (the Stiefel parameters); non-matrix leaves pass through.

All of these except ``identity`` are biased and/or noisy; the CHOCO-style
error-feedback memory in :mod:`repro.comms.layer` is what makes gossip with
them still contract to consensus.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.comms.spec import CommSpec

Array = jax.Array
PyTree = Any

_FLOAT_BITS = 32
_INDEX_BITS = 32
_EPS = 1e-12


class Compressor:
    """Base: lossless pass-through (the full-precision wire)."""

    name = "identity"

    def __call__(self, key: Array, x: Array) -> Array:
        del key
        return x

    def bits(self, shape: tuple[int, ...]) -> float:
        size = 1
        for s in shape:
            size *= s
        return float(size * _FLOAT_BITS)


IdentityCompressor = Compressor


def _per_node_scale(x: Array) -> Array:
    """max-abs over everything but the node axis, shaped to broadcast."""
    axes = tuple(range(1, x.ndim))
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True) if axes else jnp.abs(x)
    return jnp.maximum(amax / 127.0, _EPS).astype(jnp.float32)


def quantize_det(x: Array) -> tuple[Array, Array]:
    """Deterministic int8: round-to-nearest with the same per-node max-abs
    scale as :class:`Int8Stochastic`.  The all-hop compressed ``W^k``
    schedule requantizes with THIS formula at every hop, both in the stacked
    oracle and inside the shard_map megakernel — determinism is what keeps
    the two layouts' decoded int8 values identical at every hop (so their
    results differ only by FMA rounding of the final combines)."""
    scale = _per_node_scale(x)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


@dataclasses.dataclass(frozen=True)
class Int8Stochastic(Compressor):
    """Unbiased stochastic int8: q = floor(x/scale + U[0,1)), per-node scale."""

    name = "int8"

    def quantize(self, key: Array, x: Array) -> tuple[Array, Array]:
        scale = _per_node_scale(x)
        u = jax.random.uniform(key, x.shape, jnp.float32)
        q = jnp.floor(x.astype(jnp.float32) / scale + u)
        return jnp.clip(q, -127, 127).astype(jnp.int8), scale

    def dequantize(self, q: Array, scale: Array, dtype) -> Array:
        return (q.astype(jnp.float32) * scale).astype(dtype)

    def __call__(self, key: Array, x: Array) -> Array:
        q, scale = self.quantize(key, x)
        return self.dequantize(q, scale, x.dtype)

    def bits(self, shape: tuple[int, ...]) -> float:
        size = 1
        for s in shape:
            size *= s
        return float(size * 8 + shape[0] * _FLOAT_BITS)  # payload + scales


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Keep the largest-magnitude ``frac`` of entries per node, zero the rest."""

    frac: float = 0.05
    name = "topk"

    def _k(self, shape: tuple[int, ...]) -> int:
        size = 1
        for s in shape[1:]:
            size *= s
        return max(1, int(round(self.frac * size)))

    def __call__(self, key: Array, x: Array) -> Array:
        del key
        k = self._k(x.shape)

        def one(row: Array) -> Array:
            flat = row.reshape(-1)
            _, idx = jax.lax.top_k(jnp.abs(flat), k)
            return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(row.shape)

        return jax.vmap(one)(x)

    def bits(self, shape: tuple[int, ...]) -> float:
        return float(shape[0] * self._k(shape) * (_FLOAT_BITS + _INDEX_BITS))


@dataclasses.dataclass(frozen=True)
class LowRank(Compressor):
    """Randomized rank-p sketch per node for matrix leaves (ndim >= 3):
    Y = A Omega, Q = qr(Y), reconstruction Q (Q^T A).  Transmits Q and
    Q^T A, i.e. p(d + r) floats instead of d*r."""

    rank: int = 4
    name = "lowrank"

    def _eligible(self, shape: tuple[int, ...]) -> bool:
        return len(shape) >= 3 and min(shape[-2], shape[-1]) > self.rank

    def __call__(self, key: Array, x: Array) -> Array:
        if not self._eligible(x.shape):
            return x
        d, r = x.shape[-2], x.shape[-1]
        omega = jax.random.normal(key, (r, self.rank), jnp.float32)

        def one(a: Array) -> Array:
            af = a.reshape(-1, d, r).astype(jnp.float32)
            y = jnp.einsum("bdr,rp->bdp", af, omega)
            q, _ = jnp.linalg.qr(y)
            rec = jnp.einsum("bdp,bpr->bdr", q,
                             jnp.einsum("bdp,bdr->bpr", q, af))
            return rec.reshape(a.shape).astype(a.dtype)

        return jax.vmap(one)(x)

    def bits(self, shape: tuple[int, ...]) -> float:
        if not self._eligible(shape):
            return Compressor.bits(self, shape)
        lead = 1
        for s in shape[:-2]:
            lead *= s
        return float(lead * self.rank * (shape[-2] + shape[-1]) * _FLOAT_BITS)


def make_compressor(comm: CommSpec) -> Compressor:
    if comm.compressor == "none":
        return IdentityCompressor()
    if comm.compressor == "int8":
        return Int8Stochastic()
    if comm.compressor == "topk":
        return TopK(frac=comm.topk_frac)
    if comm.compressor == "lowrank":
        return LowRank(rank=comm.rank)
    raise ValueError(f"unknown compressor {comm.compressor!r}")


# ---------------------------------------------------------------------------
# pytree helpers
# ---------------------------------------------------------------------------


def compress_tree(comp: Compressor, key: Array, tree: PyTree) -> PyTree:
    """Apply ``comp`` leaf-wise with decorrelated per-leaf keys."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
    return jax.tree.unflatten(
        treedef, [comp(k, l) for k, l in zip(keys, leaves)])


def tree_bits(comp: Compressor, tree: PyTree) -> float:
    """Total bits one gossip transmission of ``tree`` puts on the wire."""
    return sum(comp.bits(tuple(l.shape)) for l in jax.tree.leaves(tree))


def tree_param_count(tree: PyTree) -> int:
    return sum(int(l.size) for l in jax.tree.leaves(tree))
