"""Fault / time-variation model for one gossip hop.

The optimizer sees a fixed ``GossipSpec`` matrix ``W``; real networks do not
cooperate.  ``ChannelModel`` turns one hop into a *sequence* of effective
matrices ``W_t`` built from ``W`` by

* **link drops** — each active edge fails i.i.d. with ``drop_rate``;
* **straggler skips** — each node sits a round out with ``straggler_rate``
  (it neither sends nor receives: all incident edges drop);
* **schedules** — ``round_robin`` cycles the color classes of a greedy
  proper edge coloring (ring, even n: the classic even/odd matchings);
  ``matching`` samples one class uniformly per round.

Dropped weight folds back into the diagonal, so every ``W_t`` is symmetric
doubly stochastic and gossip remains mean-preserving; only the *rate* of
consensus degrades.  ``empirical_mixing_rate`` measures that rate so the
consensus benchmark can put it next to the static-``W`` ``lambda_2``.

With a clean channel (no drops, no stragglers, static schedule) the hop
delegates to the exact path — ``mix_ring`` for rings — and is bit-identical
to uncompressed gossip.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.spec import CommSpec

# NOTE: repro.core.gossip is imported lazily inside methods.  The comms
# package must stay import-independent of repro.core so that either package
# can be the entry point (core.gda imports repro.comms.layer at module scope;
# a module-level import here would close the cycle through the package
# __init__s).

Array = jax.Array
PyTree = Any


def _edge_color_classes(w: np.ndarray) -> list[np.ndarray]:
    """Greedy proper edge coloring; returns per-color symmetric 0/1 masks.
    Each class is a matching (no node appears twice), so the ``matching``
    schedule can sample classes directly."""
    n = w.shape[0]
    edges = [(i, j) for i in range(n) for j in range(i + 1, n) if w[i, j] > 0]
    colors: list[list[tuple[int, int]]] = []
    busy: list[set[int]] = []
    for i, j in edges:
        for c, nodes in enumerate(busy):
            if i not in nodes and j not in nodes:
                colors[c].append((i, j))
                nodes.update((i, j))
                break
        else:
            colors.append([(i, j)])
            busy.append({i, j})
    masks = []
    for cls in colors:
        m = np.zeros((n, n), np.float32)
        for i, j in cls:
            m[i, j] = m[j, i] = 1.0
        masks.append(m)
    return masks


@dataclasses.dataclass(frozen=True, eq=False)
class ChannelModel:
    """Seeded fault simulation over a base doubly-stochastic ``w``."""

    w: np.ndarray                  # base mixing matrix (n, n), numpy/static
    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    schedule: str = "static"       # static | round_robin | matching
    topology: str = "ring"         # exact-path delegation hint
    self_weight: float = 1.0 / 3.0

    def __post_init__(self):
        if self.schedule == "static":
            masks = [(np.asarray(self.w) > 0).astype(np.float32)
                     * (1.0 - np.eye(self.w.shape[0], dtype=np.float32))]
        else:
            masks = _edge_color_classes(np.asarray(self.w))
        if not masks:  # edgeless graph (n == 1): W_t degenerates to identity
            masks = [np.zeros_like(np.asarray(self.w, np.float32))]
        object.__setattr__(self, "_subset_masks", np.stack(masks))

    @classmethod
    def for_gossip(cls, gossip, comm: CommSpec) -> "ChannelModel":
        return cls(w=gossip.matrix, drop_rate=comm.drop_rate,
                   straggler_rate=comm.straggler_rate, schedule=comm.schedule,
                   topology=gossip.topology, self_weight=gossip.self_weight)

    # -- properties ---------------------------------------------------------

    @property
    def n(self) -> int:
        return self.w.shape[0]

    @property
    def n_subsets(self) -> int:
        return self._subset_masks.shape[0]

    @property
    def trivial(self) -> bool:
        return (self.drop_rate == 0.0 and self.straggler_rate == 0.0
                and self.schedule == "static")

    @property
    def lam2(self) -> float:
        from repro.core.gossip import second_largest_eigenvalue
        return second_largest_eigenvalue(np.asarray(self.w))

    # -- per-round effective matrix ----------------------------------------

    def _round_masks(self, rnd: Array | int, key: Array
                     ) -> tuple[Array, Array]:
        """(scheduled, effective) symmetric 0/1 link masks for round ``rnd``.

        The random-draw sequence here IS the round's fault realization:
        ``w_t`` consumes it for mixing and ``link_stats`` re-derives it with
        the same keys for accounting, so counted drops match applied drops
        exactly."""
        n = self.n
        masks = jnp.asarray(self._subset_masks)
        if self.schedule == "round_robin":
            sched = jnp.take(masks, jnp.mod(rnd, self.n_subsets), axis=0)
        elif self.schedule == "matching":
            k_sched, key = jax.random.split(key)
            sched = jnp.take(masks, jax.random.randint(
                k_sched, (), 0, self.n_subsets), axis=0)
        else:
            sched = masks[0]
        mask = sched
        if self.drop_rate > 0.0:
            k_drop, key = jax.random.split(key)
            keep = jax.random.bernoulli(
                k_drop, 1.0 - self.drop_rate, (n, n)).astype(jnp.float32)
            keep = jnp.triu(keep, 1)
            mask = mask * (keep + keep.T)
        if self.straggler_rate > 0.0:
            k_straggle, key = jax.random.split(key)
            up = jax.random.bernoulli(
                k_straggle, 1.0 - self.straggler_rate, (n,)).astype(jnp.float32)
            mask = mask * (up[:, None] * up[None, :])
        return sched, mask

    def w_t(self, rnd: Array | int, key: Array) -> Array:
        """Effective mixing matrix for round ``rnd`` (jit-safe, ``rnd`` may
        be traced).  Always symmetric doubly stochastic."""
        n = self.n
        w = jnp.asarray(self.w, jnp.float32)
        off = w * (1.0 - jnp.eye(n, dtype=jnp.float32))
        _, mask = self._round_masks(rnd, key)
        w_off = off * mask
        return w_off + jnp.diag(1.0 - jnp.sum(w_off, axis=1))

    def link_stats(self, rnd: Array | int, key: Array
                   ) -> tuple[Array, Array]:
        """(scheduled, active) undirected link counts for round ``rnd`` —
        the telemetry wire counters' dynamic inputs (dropped = scheduled -
        active).  Same draws as ``w_t`` for the same (rnd, key)."""
        sched, mask = self._round_masks(rnd, key)
        return jnp.sum(sched) / 2.0, jnp.sum(mask) / 2.0

    def ring_link_weights(self, rnd: Array | int, key: Array
                          ) -> tuple[Array, Array, Array]:
        """Round ``rnd``'s effective ring weights as per-link vectors:
        ``(self, left, right)`` of shape (n,) — the three non-zero diagonals
        of ``w_t``.  This is what the shard_map backend consumes: channel
        faults become ppermute-payload *filters*, and model-sized data never
        meets a dense (n, n) matrix."""
        wt = self.w_t(rnd, key)
        n = self.n
        i = jnp.arange(n)
        return wt[i, i], wt[i, (i - 1) % n], wt[i, (i + 1) % n]

    # -- mixing -------------------------------------------------------------

    def mix_hop(self, tree: PyTree, rnd: Array | int, key: Array) -> PyTree:
        """One gossip hop through the channel.  A trivial channel takes the
        exact path (``mix_ring`` for rings) and is bit-identical to it."""
        if self.trivial:
            if self.topology == "ring":
                from repro.core.gossip import mix_ring
                return mix_ring(tree, steps=1, self_weight=self.self_weight)
            w = jnp.asarray(self.w, jnp.float32)
            return jax.tree.map(
                lambda x: jnp.einsum("ij,j...->i...", w.astype(x.dtype), x),
                tree)
        wt = self.w_t(rnd, key)
        return jax.tree.map(
            lambda x: jnp.einsum("ij,j...->i...", wt.astype(x.dtype), x), tree)

    def mix(self, tree: PyTree, rnd: Array | int, key: Array,
            steps: int = 1) -> PyTree:
        for h in range(steps):
            tree = self.mix_hop(tree, rnd * steps + h,
                                jax.random.fold_in(key, h))
        return tree

    # -- diagnostics --------------------------------------------------------

    def empirical_mixing_rate(self, rounds: int = 64, seed: int = 0,
                              dim: int = 32) -> dict:
        """Per-round disagreement contraction under the sampled W_t sequence,
        to compare against the static-W ``lambda_2``."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(jax.random.fold_in(key, 0xA11CE), (self.n, dim))
        err0 = float(jnp.linalg.norm(x - jnp.mean(x, 0, keepdims=True)))
        errs = []
        for t in range(rounds):
            x = self.mix_hop(x, t, jax.random.fold_in(key, t))
            errs.append(float(jnp.linalg.norm(
                x - jnp.mean(x, 0, keepdims=True))))
        rate = (errs[-1] / err0) ** (1.0 / rounds) if err0 > 0 else 0.0
        return {"per_round_rate": rate, "lambda2_static": self.lam2,
                "final_over_initial": errs[-1] / max(err0, 1e-30)}
