"""Elastic asynchronous gossip: churn and staleness as an execution mode.

:class:`repro.comms.channel.ChannelModel` *simulates* link faults — every
node still computes, and a dropped link is a counterfactual on an otherwise
static membership.  This module promotes that machinery to a supported
execution mode in which departures and stragglers are *real*:

* a :class:`ChurnSchedule` (scripted timeline or seeded Markov draw) decides
  which nodes are **members** each round;
* sends to non-members are skipped and each dead link's weight folds back
  into the two endpoint diagonals, so every realized ``W_t`` stays symmetric
  doubly stochastic over the **live subgraph** (a departed node's row is
  exactly the identity row — it neither sends nor receives);
* a member that fails to publish a round (straggler) stays mixable for up to
  ``tau`` rounds through its **last-received buffer** — bounded-delay
  stale-hop tolerance; past ``tau`` the link is treated as dropped.  At
  ``tau = 0`` this degenerates bit-for-bit to the channel model's drop
  semantics (same key-split order, same mask algebra, same fold formula,
  same backend expressions);
* a (re)joining node is re-initialized from its live neighbours'
  ``geometry.consensus_mean`` (x/y slots, projected through the manifold
  map the optimizer registers) and from zeros (dual/tracking slots and the
  CHOCO hat memory), then participates normally.

All of it is carried as one traced optimizer-state leaf:
:class:`Membership` rides in ``CommState.elastic`` exactly like the CHOCO
hats, so the jitted step stays a pure function and a fixed seed replays the
same churn realization bit-for-bit.

In **compressed** mode no separate stale buffers exist: the CHOCO hats *are*
the last-received public copies, so staleness tolerance falls out of gating
the hat fold by the publish mask — a non-publishing member's hat simply
stays put and keeps being mixed until it ages out.

Elastic mode replaces the simulation-mode channel: configure fault rates on
:class:`ElasticSpec`, not on ``CommSpec`` (mixing both raises).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.backend import MixBackend
from repro.comms.layer import CommEngine, CommState
from repro.comms.spec import CommSpec

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# churn schedules
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _scripted_timeline(events: tuple, n: int) -> np.ndarray:
    """Cumulative active-mask timeline (horizon+1, n) from (round, action,
    node) events; row t is the membership in force during round t, rows past
    the last event repeat it (the engine clamps the index)."""
    horizon = max(r for r, _, _ in events)
    tl = np.ones((horizon + 1, n), np.float32)
    cur = np.ones(n, np.float32)
    for t in range(horizon + 1):
        for r, action, node in events:
            if r == t:
                cur[node] = 0.0 if action == "leave" else 1.0
        tl[t] = cur
    return tl


@dataclasses.dataclass(frozen=True)
class ChurnSchedule:
    """Who is a member each round.

    ``static``   — everyone, always (elastic machinery stays off unless the
    spec also carries fault rates).
    ``scripted`` — an explicit event timeline ``((round, "leave"|"join",
    node), ...)``; membership is cumulative and repeats past the last event.
    ``random``   — seeded per-round Markov draw: a member leaves with
    ``leave_rate``, a non-member rejoins with ``join_rate``; node 0 is
    pinned live so the subgraph never empties.
    """

    kind: str = "static"            # static | scripted | random
    events: tuple = ()              # ((round, "leave"|"join", node), ...)
    leave_rate: float = 0.0
    join_rate: float = 0.5

    def __post_init__(self):
        if self.kind not in ("static", "scripted", "random"):
            raise ValueError(f"unknown churn kind {self.kind!r}")
        object.__setattr__(self, "events", tuple(tuple(e)
                                                 for e in self.events))

    @property
    def enabled(self) -> bool:
        if self.kind == "scripted":
            return len(self.events) > 0
        return self.kind == "random"

    def active(self, prev: Array, rnd: Array | int, key: Array) -> Array:
        """Membership mask f32[n] in force during round ``rnd``, given the
        previous round's mask (jit-safe; ``rnd`` may be traced)."""
        n = prev.shape[0]
        if self.kind == "scripted" and self.events:
            tl = jnp.asarray(_scripted_timeline(self.events, n))
            idx = jnp.clip(jnp.asarray(rnd, jnp.int32), 0, tl.shape[0] - 1)
            return jnp.take(tl, idx, axis=0)
        if self.kind == "random":
            k_leave, k_join = jax.random.split(key)
            stay = jax.random.bernoulli(
                k_leave, 1.0 - self.leave_rate, (n,)).astype(jnp.float32)
            come = jax.random.bernoulli(
                k_join, self.join_rate, (n,)).astype(jnp.float32)
            act = jnp.where(prev > 0, stay, come)
            return act.at[0].set(1.0)
        return prev


@dataclasses.dataclass(frozen=True)
class ElasticSpec:
    """Execution-mode config hung on ``GossipSpec.elastic``.

    ``tau`` is the stale-hop tolerance: a member that missed publishing for
    at most ``tau`` consecutive rounds keeps its links alive through its
    last-received buffer; ``tau = 0`` reproduces the channel model's hard
    drop semantics bit-for-bit.  ``drop_rate`` / ``straggler_rate`` are the
    execution-mode twins of the ``CommSpec`` simulation knobs (configure
    them here, not there, when elastic mode is on).
    """

    churn: ChurnSchedule = ChurnSchedule()
    tau: int = 0
    drop_rate: float = 0.0
    straggler_rate: float = 0.0
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return (self.churn.enabled or self.drop_rate > 0.0
                or self.straggler_rate > 0.0)


class Membership(NamedTuple):
    """Traced elastic state, one leaf in ``CommState.elastic``.

    ``round`` is the last round whose churn transition was committed — the
    first slot mixed in a round advances membership, later slots of the same
    round see the committed masks (idempotency guard: one optimizer step
    mixes several slots against one shared ``CommState``).
    """

    round: Array                    # i32 scalar, -1 before the first round
    active: Array                   # f32[n] current membership mask
    prev_active: Array              # f32[n] previous round's mask
    staleness: dict[str, Array]     # slot -> i32[n] rounds since last publish
    stale: dict[str, PyTree]        # slot -> last-published copy
    #                                 (uncompressed tau>0 only; compressed
    #                                 mode reuses the CHOCO hats)


class RoundView(NamedTuple):
    """Everything one (slot, round) realizes, derived in a single place so
    the mix, the wire counters, and the contracts validator agree by
    construction."""

    active: Array                   # f32[n] committed membership
    prev: Array                     # f32[n] previous round's membership
    joined: Array                   # f32[n] 1 where a node joined this round
    publish: Array                  # f32[n] members that sent this round
    fresh: Array                    # f32[n] mixable endpoints (<= tau stale)
    link_mask: Array                # f32[n,n] symmetric realized link mask
    wt: Array                       # f32[n,n] realized mixing matrix
    staleness: Array                # i32[n] updated per-slot counters
    committed_round: Array          # i32 scalar membership round watermark
    sched_live: Array               # scheduled undirected links, live pairs
    act_links: Array                # realized undirected links


def _bcast(v: Array, leaf: Array) -> Array:
    """Broadcast a per-node vector over a stacked (n, ...) leaf."""
    return v.astype(leaf.dtype).reshape(v.shape + (1,) * (leaf.ndim - 1))


class ElasticEngine(CommEngine):
    """``CommEngine`` whose gossip rounds run over a churning membership."""

    def __init__(self, gossip, backend: Optional[MixBackend] = None):
        es: Optional[ElasticSpec] = getattr(gossip, "elastic", None)
        assert es is not None and es.enabled, \
            "ElasticEngine requires an enabled GossipSpec.elastic"
        comm = gossip.comm
        if comm is None or not comm.enabled:
            comm = CommSpec()          # uncompressed, clean, seed 0
        if comm.channel_active or comm.schedule != "static":
            raise ValueError(
                "elastic mode replaces the simulation ChannelModel: move "
                "drop_rate/straggler_rate onto ElasticSpec and keep "
                "CommSpec.schedule='static'")
        self.elastic = es
        self._setup(gossip, comm, backend)

    # the fused int8 hop bakes in clean static ring weights; elastic rounds
    # carry a per-round W_t, so they stay on the explicit-matrix path
    def _use_fused_hop(self) -> bool:
        return False

    # -- state --------------------------------------------------------------

    def init_state(self, slots: dict[str, PyTree]) -> CommState:
        base = super().init_state(slots)
        n = self.gossip.n_nodes
        # active/prev_active must be DISTINCT buffers: the jitted step
        # donates the whole state, and XLA rejects donating one buffer twice
        full = jnp.ones((n,), jnp.float32)
        full2 = jnp.ones((n,), jnp.float32)
        staleness = {name: jnp.zeros((n,), jnp.int32) for name in slots}
        # jnp.copy: stale buffers must not alias the live slot arrays or
        # donated optimizer steps would invalidate them
        stale = ({name: jax.tree.map(jnp.copy, tree)
                  for name, tree in slots.items()}
                 if self.elastic.tau > 0 and not self.comm.compressed else {})
        mem = Membership(round=jnp.asarray(-1, jnp.int32), active=full,
                         prev_active=full2, staleness=staleness, stale=stale)
        return base._replace(elastic=mem)

    # -- per-round realization ---------------------------------------------

    def round_view(self, state: CommState, slot: str, rnd: Array | int
                   ) -> RoundView:
        """Commit (or replay) round ``rnd``'s membership transition and
        derive the slot's publish mask, freshness, and realized ``W_t``.

        The fault draw mirrors ``ChannelModel._round_masks`` key-for-key
        (hop-0 fold, drop split before straggler split) so that at full
        membership and ``tau = 0`` the realized matrix is bit-identical to
        the simulation channel's ``w_t`` for the same ``(rnd, key)``.
        """
        es: Membership = state.elastic
        spec = self.elastic
        n = self.gossip.n_nodes
        rnd = jnp.asarray(rnd, jnp.int32)

        # membership transition, committed once per round
        fresh_round = rnd > es.round
        churn_key = jax.random.fold_in(
            jax.random.PRNGKey(spec.seed), rnd)
        act_new = spec.churn.active(es.active, rnd, churn_key)
        active = jnp.where(fresh_round, act_new, es.active)
        prev = jnp.where(fresh_round, es.active, es.prev_active)
        joined = active * (1.0 - prev)
        committed = jnp.where(fresh_round, rnd, es.round)

        # per-slot fault draw — ChannelModel.mix's hop-0 key
        key = jax.random.fold_in(self.chan_key(state, slot, rnd), 0)
        sched = jnp.asarray(self.channel._subset_masks)[0]
        link_keep = jnp.ones((n, n), jnp.float32)
        if spec.drop_rate > 0.0:
            k_drop, key = jax.random.split(key)
            keep = jax.random.bernoulli(
                k_drop, 1.0 - spec.drop_rate, (n, n)).astype(jnp.float32)
            keep = jnp.triu(keep, 1)
            link_keep = keep + keep.T
        up = jnp.ones((n,), jnp.float32)
        if spec.straggler_rate > 0.0:
            k_straggle, key = jax.random.split(key)
            up = jax.random.bernoulli(
                k_straggle, 1.0 - spec.straggler_rate, (n,)
            ).astype(jnp.float32)

        publish = up * active
        staleness = jnp.where(publish > 0, 0, es.staleness[slot] + 1)
        fresh = (staleness <= spec.tau).astype(jnp.float32) * active
        mask = sched * link_keep * (fresh[:, None] * fresh[None, :])

        w = jnp.asarray(self.gossip.matrix, jnp.float32)
        off = w * (1.0 - jnp.eye(n, dtype=jnp.float32))
        w_off = off * mask
        wt = w_off + jnp.diag(1.0 - jnp.sum(w_off, axis=1))

        live_pairs = sched * (active[:, None] * active[None, :])
        return RoundView(active=active, prev=prev, joined=joined,
                         publish=publish, fresh=fresh, link_mask=mask,
                         wt=wt, staleness=staleness,
                         committed_round=committed,
                         sched_live=jnp.sum(live_pairs) / 2.0,
                         act_links=jnp.sum(mask) / 2.0)

    def realized_wt(self, state: CommState, slot: str, rnd: Array | int
                    ) -> Array:
        """The effective mixing matrix this slot's round-``rnd`` mix applies
        — the contracts validator's input."""
        return self.round_view(state, slot, rnd).wt

    def link_stats(self, state: CommState, slot: str, rnd: Array | int
                   ) -> tuple[Array, Array]:
        """(scheduled-live, realized) undirected link counts — the wire
        counters' dynamic inputs; dropped = scheduled-live - realized."""
        view = self.round_view(state, slot, rnd)
        return view.sched_live, view.act_links

    # -- join protocol ------------------------------------------------------

    def _reinit_joined(self, slot: str, tree: PyTree, view: RoundView
                       ) -> PyTree:
        """Replace just-joined nodes' rows: consensus mean of live
        neighbours for primal slots (projected through the registered
        manifold map, falling back to the global live mean on an isolated
        join), zeros for dual/tracking slots."""
        joined = view.joined
        if slot not in self.manifolds and slot not in ("x", "y"):
            return jax.tree.map(
                lambda z: z * (1.0 - _bcast(joined, z)), tree)

        nbr = jnp.asarray(self.channel._subset_masks)[0]
        wrow = nbr * view.prev[None, :]                    # live neighbours
        cnt = jnp.sum(wrow, axis=1)
        g_cnt = jnp.maximum(jnp.sum(view.prev), 1.0)

        def mean(leaf):
            num = jnp.einsum("ij,j...->i...", wrow.astype(leaf.dtype), leaf)
            g = jnp.einsum("j,j...->...", view.prev.astype(leaf.dtype),
                           leaf) / g_cnt.astype(leaf.dtype)
            m = num / _bcast(jnp.maximum(cnt, 1.0), leaf)
            return jnp.where(_bcast((cnt > 0).astype(jnp.float32), leaf) > 0,
                             m, g[None])

        means = jax.tree.map(mean, tree)
        mm = self.manifolds.get(slot)
        if mm is not None:
            from repro.geometry import base as geometry
            mmap = geometry.as_manifold_map(mm)
            means = jax.tree.map(
                lambda m, leaf: m.project(leaf), mmap, means,
                is_leaf=lambda s: isinstance(s, geometry.Manifold))
        return jax.tree.map(
            lambda z, m: jnp.where(_bcast(joined, z) > 0, m, z), tree, means)

    # -- one elastic gossip round ------------------------------------------

    def mix(self, state: CommState, slot: str, tree: PyTree, *,
            steps: Optional[int] = None, rnd: Array | int = 0
            ) -> tuple[PyTree, CommState]:
        s = self.gossip.k if steps is None else steps
        if self.gossip.n_nodes == 1 or s == 0:
            return tree, state
        view = self.round_view(state, slot, rnd)
        es: Membership = state.elastic
        tree = self._reinit_joined(slot, tree, view)

        new_staleness = dict(es.staleness)
        new_staleness[slot] = view.staleness
        new_stale = dict(es.stale)

        if not self.comm.compressed:
            if self.elastic.tau > 0 and slot in es.stale:
                pub = view.publish
                stale_old = es.stale[slot]
                z = tree
                for _ in range(s):
                    # each endpoint contributes its published value when it
                    # sent this round, its last-received buffer otherwise;
                    # the self-weight always applies to the true local state
                    b = jax.tree.map(
                        lambda x, st: _bcast(pub, x) * x
                        + (1.0 - _bcast(pub, x)) * st.astype(x.dtype),
                        z, stale_old)
                    mixed_b = self.backend.mix_wt(self.gossip, b, view.wt,
                                                  steps=1)
                    d = jnp.diag(view.wt)
                    z = jax.tree.map(
                        lambda mb, x, bb: mb + _bcast(d, x) * (x - bb),
                        mixed_b, z, b)
                new_stale[slot] = jax.tree.map(
                    lambda st, x: jnp.where(_bcast(pub, x) > 0, x,
                                            st.astype(x.dtype)),
                    stale_old, tree)
                mixed = z
            else:
                mixed = self.backend.mix_wt(self.gossip, tree, view.wt,
                                            steps=s)
            mem = Membership(round=view.committed_round, active=view.active,
                             prev_active=view.prev, staleness=new_staleness,
                             stale=new_stale)
            return mixed, state._replace(elastic=mem)

        # compressed: the CHOCO hats double as the stale buffers.  A joining
        # node's hat resets to zero; only publishers fold a payload, so a
        # straggler's public copy stays put and keeps mixing until its links
        # age out of `fresh`.
        k_quant, _ = self._keys(state, slot, rnd)
        pub = view.publish
        hat = state.hats[slot]
        hat_base = jax.tree.map(
            lambda h: h * (1.0 - _bcast(view.joined, h)), hat)
        source = (jax.tree.map(lambda x, h: x - h, tree, hat_base)
                  if self.comm.error_feedback else tree)
        payload, _ = self._compress(k_quant, source)
        upd = (jax.tree.map(lambda h, p: h + p, hat_base, payload)
               if self.comm.error_feedback else payload)
        hat_new = jax.tree.map(
            lambda u, h: jnp.where(_bcast(pub, u) > 0, u, h), upd, hat_base)
        mixed_hat = self.backend.mix_wt(self.gossip, hat_new, view.wt,
                                        steps=s)
        gamma, deltas = self._gamma(state, slot, source, payload)
        # inactive rows of W_t are identity rows, so mixed_hat == hat_new
        # there and departed nodes receive a zero consensus delta for free
        mixed = jax.tree.map(lambda x, mh, h: x + gamma * (mh - h),
                             tree, mixed_hat, hat_new)
        new_hats = dict(state.hats)
        new_hats[slot] = hat_new
        mem = Membership(round=view.committed_round, active=view.active,
                         prev_active=view.prev, staleness=new_staleness,
                         stale=new_stale)
        return mixed, CommState(hats=new_hats, key=state.key, deltas=deltas,
                                elastic=mem)
