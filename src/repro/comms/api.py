"""Typed facade of the comms subsystem — Protocols + the backend registry.

This module is deliberately import-light (stdlib ``typing`` only, no jax):
``repro.core`` annotates ``GossipSpec.comm`` / ``GossipSpec.backend`` /
``GossipSpec.elastic`` against these Protocols under ``TYPE_CHECKING``
without importing any comms machinery at runtime, which kills the old
"``comm: object | None``" loose typing while preserving the one-way import
convention (comms never imports core at module scope; core may import
comms).

Three structural types:

* :class:`CommLike`     — the ``CommSpec`` surface the optimizers and the
  engine consume (compression knobs + channel fault rates);
* :class:`ElasticLike`  — the ``ElasticSpec`` surface (churn schedule,
  stale-hop tolerance ``tau``, execution-mode fault rates);
* :class:`MixBackendProtocol` — how gossip hops execute (stacked
  roll/einsum vs shard_map ppermute); ``repro.comms.backend.MixBackend``
  is the runtime-checkable twin with precise jax types.

Plus the **backend string registry**: ``GossipSpec.backend`` and the
``mix_backend`` config knob accept ``"stacked" | "shard_map"`` names;
``resolve_backend`` / ``make_backend`` construct through
:data:`BACKENDS` instead of ad-hoc isinstance/if-else plumbing, and
third-party backends can :func:`register_backend` themselves.
"""
from __future__ import annotations

from typing import Any, Callable, Protocol, runtime_checkable

__all__ = ["CommLike", "ElasticLike", "MixBackendProtocol", "BACKENDS",
           "register_backend", "backend_names"]


@runtime_checkable
class CommLike(Protocol):
    """What a ``GossipSpec.comm`` value must look like (see ``CommSpec``)."""

    compressor: str
    error_feedback: bool
    gamma: float
    drop_rate: float
    straggler_rate: float
    schedule: str
    seed: int

    @property
    def compressed(self) -> bool: ...

    @property
    def channel_active(self) -> bool: ...

    @property
    def enabled(self) -> bool: ...


@runtime_checkable
class ElasticLike(Protocol):
    """What a ``GossipSpec.elastic`` value must look like (see
    ``repro.comms.elastic.ElasticSpec``)."""

    tau: int
    drop_rate: float
    straggler_rate: float
    seed: int

    @property
    def enabled(self) -> bool: ...


@runtime_checkable
class MixBackendProtocol(Protocol):
    """Strategy interface between the gossip math and the wire.

    The jax-typed runtime twin lives in :mod:`repro.comms.backend`
    (``MixBackend``); this copy exists so ``repro.core`` can type-check
    against the surface without importing jax-heavy comms modules.
    """

    name: str

    def mix(self, spec: Any, tree: Any, steps: int) -> Any: ...

    def mix_hop(self, spec: Any, tree: Any) -> Any: ...

    def mix_channel(self, spec: Any, channel: Any, tree: Any, rnd: Any,
                    key: Any, steps: int) -> Any: ...

    def mix_wt(self, spec: Any, tree: Any, wt: Any, *,
               steps: int = 1) -> Any: ...

    def quant_ring_hop(self, spec: Any, q: Any, scale: Any, *,
                       out_dtype: Any = ...) -> Any: ...

    def quant_ring_hops(self, spec: Any, x: Any, steps: int, *,
                        out_dtype: Any = ...) -> Any: ...

    def est_hop_bytes(self, spec: Any, tree: Any) -> float: ...

    def est_quant_hop_bytes(self, spec: Any, tree: Any) -> float: ...


# ---------------------------------------------------------------------------
# backend string registry
# ---------------------------------------------------------------------------

#: name -> factory(mesh=None, axis="node", fuse="auto", fuse_depth=None).
#: Populated by :mod:`repro.comms.backend` at import time ("stacked",
#: "shard_map"); extensible via :func:`register_backend`.
BACKENDS: dict[str, Callable[..., Any]] = {}


def register_backend(name: str, factory: Callable[..., Any]) -> None:
    """Register a mix-backend factory under a config-string name."""
    BACKENDS[name] = factory


def backend_names() -> list[str]:
    return sorted(BACKENDS)
