"""The comms engine: compressed, fault-tolerant gossip with error feedback.

``CommEngine`` owns everything between an optimizer's ``mix`` call and the
wire.  One compressed gossip round for a slot (``x``/``y``/``u``/``v``) is
the CHOCO scheme:

    q_i      = C(x_i - x_hat_i)          # the only thing transmitted
    x_hat_i += q_i                       # every replica folds the payload
    x_i     += gamma * ([W_t^s x_hat]_i - x_hat_i)

With the identity compressor and ``gamma = 1`` this reduces exactly to
``x <- W^s x``; with a contractive/unbiased compressor the hat memory keeps
the *error feedback* residual in the loop so consensus error still goes to
zero (naive quantized gossip — ``error_feedback=False`` — plateaus at the
compressor's noise floor instead).

The hop itself runs through :class:`repro.comms.channel.ChannelModel`
(drops / stragglers / schedules); a trivial channel takes the exact
``mix_ring`` path.  For int8 payloads on a clean ring the first hop is the
fused Pallas ``quant_mix`` kernel: ``W(hat + dq(q)) = W hat + [dequantize +
3-way combine of the int8 wire buffers]``.

*How* any of these hops execute — stacked roll/einsum over leaf axis 0, or
``shard_map``/``ppermute`` neighbour exchange over the mesh's node axis —
is the engine's :class:`repro.comms.backend.MixBackend`; every wire touch
in this module routes through it, so EF-int8 gossip and the fused hop work
identically under both layouts.

With ``gamma_mode="adaptive"`` the consensus step is derived from the
compressor's tracked contraction delta (see :meth:`CommEngine._gamma`)
instead of the ``CommSpec.gamma`` constant.

Optimizers thread one :class:`CommState` pytree leaf through their jitted
step; :func:`make_mixer` packages the slot-keyed routing so the four
baselines and DRGDA/DRSGDA share the integration shim.
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comms.backend import MixBackend, resolve_backend
from repro.comms.channel import ChannelModel
from repro.comms.compress import (Int8Stochastic, compress_tree,
                                  make_compressor, tree_bits,
                                  tree_param_count)
from repro.comms.spec import CommSpec

Array = jax.Array
PyTree = Any


class CommState(NamedTuple):
    """Per-node communication memory, carried as one optimizer-state leaf."""
    hats: dict[str, PyTree]   # CHOCO public copies, one per mixed slot
    key: Array                # base PRNG for quantization + channel faults
    # per-slot EMA of the compressor's empirical contraction delta
    # (E||C(r) - r||^2 <= (1 - delta)||r||^2); only tracked when
    # CommSpec.gamma_mode == "adaptive"
    deltas: Any = None
    # elastic execution-mode membership (repro.comms.elastic.Membership);
    # None outside elastic mode so existing states keep their treedef
    elastic: Any = None


def _salt(slot: str) -> int:
    return zlib.crc32(slot.encode()) & 0x7FFFFFFF


class CommEngine:
    """Static compression + channel machinery for one ``GossipSpec``."""

    def __init__(self, gossip, backend: Optional[MixBackend] = None):
        comm: Optional[CommSpec] = gossip.comm
        assert comm is not None and comm.enabled, \
            "CommEngine requires an enabled GossipSpec.comm"
        self._setup(gossip, comm, backend)

    def _setup(self, gossip, comm: CommSpec,
               backend: Optional[MixBackend]) -> None:
        """Shared constructor body — ``ElasticEngine`` calls this with a
        substitute (disabled) ``CommSpec`` when the gossip spec carries no
        comm config of its own."""
        self.gossip = gossip
        self.comm = comm
        self.compressor = make_compressor(comm)
        self.channel = ChannelModel.for_gossip(gossip, comm)
        # how hops execute: stacked roll/einsum or shard_map ppermute —
        # every wire touch below goes through this strategy object
        self.backend: MixBackend = backend if backend is not None \
            else resolve_backend(gossip)
        # slot -> manifold map, registered by the optimizer so the elastic
        # join protocol can project re-initialized slots; unused here
        self.manifolds: dict[str, Any] = {}

    def register_manifolds(self, maps: dict[str, Any]) -> None:
        """Record per-slot manifold maps (``{"x": problem.manifold_map}``).
        The base engine never reads them; the elastic engine projects a
        rejoining node's consensus-mean re-init through them."""
        self.manifolds.update({k: v for k, v in maps.items() if v is not None})

    # -- state --------------------------------------------------------------

    def init_state(self, slots: dict[str, PyTree]) -> CommState:
        # channel-only configs never read the CHOCO memory — don't carry
        # model-sized dead buffers through every donated optimizer step
        hats = ({name: jax.tree.map(jnp.zeros_like, tree)
                 for name, tree in slots.items()}
                if self.comm.compressed else {})
        deltas = ({name: jnp.ones((), jnp.float32) for name in slots}
                  if self.comm.compressed and self.comm.adaptive_gamma
                  else None)
        return CommState(hats=hats, key=jax.random.PRNGKey(self.comm.seed),
                         deltas=deltas)

    # -- accounting (static, pure Python over shapes) -----------------------

    def bits_per_mix(self, tree: PyTree) -> float:
        return tree_bits(self.compressor, tree)

    def bits_per_param(self, tree: PyTree) -> float:
        return tree_bits(self.compressor, tree) / max(tree_param_count(tree), 1)

    def wire_round_bytes(self, tree: PyTree, steps: int
                         ) -> tuple[float, float]:
        """(wire, raw) bytes for one ``steps``-hop gossip round over a clean
        channel — the telemetry wire counters' static inputs.

        ``raw`` is ``steps`` full-precision hops of the backend's
        ``est_hop_bytes`` oracle.  A compressed round ships the payload
        ``C(x - x_hat)`` to every neighbour once (2 on a ring, n-1 dense)
        plus ``steps - 1`` hat hops — full-precision under
        ``quant_hops="first"``, int8 (+ per-row scales) when the all-hop
        schedule requantizes at every hop — exactly how ``_gossip_hats``
        executes.  wire/raw is the round's realized compression ratio.
        """
        per_hop = self.backend.est_hop_bytes(self.gossip, tree)
        raw = float(steps) * per_hop
        if not self.comm.compressed:
            return raw, raw
        payload = tree_bits(self.compressor, tree) / 8.0
        fanout = 2.0 if self.gossip.topology == "ring" \
            else float(max(self.gossip.n_nodes - 1, 1))
        per_tail = per_hop
        if self.comm.quant_hops == "all" and self._use_fused_hop():
            per_tail = self.backend.est_quant_hop_bytes(self.gossip, tree)
        wire = fanout * payload + float(max(steps - 1, 0)) * per_tail
        return wire, raw

    def _keys(self, state: CommState, slot: str, rnd: Array | int
              ) -> tuple[Array, Array]:
        """(k_quant, k_chan) for one round — the single derivation both the
        mix and the telemetry accounting (``chan_key``) share."""
        key = jax.random.fold_in(
            jax.random.fold_in(state.key, _salt(slot)), rnd)
        return tuple(jax.random.split(key))

    def chan_key(self, state: CommState, slot: str, rnd: Array | int) -> Array:
        return self._keys(state, slot, rnd)[1]

    # -- one compressed gossip round ---------------------------------------

    def mix(self, state: CommState, slot: str, tree: PyTree, *,
            steps: Optional[int] = None, rnd: Array | int = 0
            ) -> tuple[PyTree, CommState]:
        s = self.gossip.k if steps is None else steps
        if self.gossip.n_nodes == 1 or s == 0:
            return tree, state
        k_quant, k_chan = self._keys(state, slot, rnd)

        if not self.comm.compressed:
            # channel-only: full-precision payload over the faulty links
            return (self.backend.mix_channel(self.gossip, self.channel, tree,
                                             rnd, k_chan, steps=s), state)

        hat = state.hats[slot]
        source = (jax.tree.map(lambda x, h: x - h, tree, hat)
                  if self.comm.error_feedback else tree)
        payload, wire = self._compress(k_quant, source)
        hat_new = (jax.tree.map(lambda h, p: h + p, hat, payload)
                   if self.comm.error_feedback else payload)
        mixed_hat = self._gossip_hats(hat_new, hat, wire, s, rnd, k_chan)
        gamma, deltas = self._gamma(state, slot, source, payload)
        mixed = jax.tree.map(lambda x, mh, h: x + gamma * (mh - h),
                             tree, mixed_hat, hat_new)
        new_hats = dict(state.hats)
        new_hats[slot] = hat_new
        return mixed, CommState(hats=new_hats, key=state.key, deltas=deltas)

    def _gamma(self, state: CommState, slot: str, source: PyTree,
               payload: PyTree):
        """Consensus step size on the hats.

        ``fixed``: the hand-tuned ``CommSpec.gamma`` constant.  ``adaptive``:
        track the compressor's empirical contraction
        ``delta = 1 - ||C(r) - r||^2 / ||r||^2`` per slot as an EMA and step
        with it — CHOCO's admissible step scales with delta, so a lossless
        wire recovers gamma -> 1 and an aggressive compressor automatically
        backs off instead of trusting a config constant.
        """
        if not self.comm.adaptive_gamma:
            return self.comm.gamma, state.deltas
        src_sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                     for l in jax.tree.leaves(source))
        err_sq = sum(jnp.sum(jnp.square((p - s).astype(jnp.float32)))
                     for p, s in zip(jax.tree.leaves(payload),
                                     jax.tree.leaves(source)))
        obs = jnp.clip(1.0 - err_sq / (src_sq + 1e-30), 0.0, 1.0)
        ema = self.comm.gamma_ema
        delta = ema * state.deltas[slot] + (1.0 - ema) * obs
        gamma = jnp.clip(delta, self.comm.gamma_min, 1.0)
        deltas = dict(state.deltas)
        deltas[slot] = delta
        return gamma, deltas

    # -- internals ----------------------------------------------------------

    def _compress(self, key: Array, tree: PyTree):
        """Leaf-wise compression; for int8 also returns the raw wire buffers
        (q, scale) so the fused kernel can consume them."""
        comp = self.compressor
        if isinstance(comp, Int8Stochastic):
            # same per-leaf key decorrelation as compress_tree, but keeping
            # the int8 payloads around for the fused quant_mix hop
            leaves, treedef = jax.tree.flatten(tree)
            keys = [jax.random.fold_in(key, i) for i in range(len(leaves))]
            qs, scales = zip(*(comp.quantize(k, l)
                               for k, l in zip(keys, leaves)))
            payload = jax.tree.unflatten(
                treedef, [comp.dequantize(q, sc, l.dtype)
                          for q, sc, l in zip(qs, scales, leaves)])
            return payload, (list(qs), list(scales), treedef)
        return compress_tree(comp, key, tree), None

    def _use_fused_hop(self) -> bool:
        return (self.comm.fuse_kernel and self.channel.trivial
                and self.gossip.topology == "ring"
                and isinstance(self.compressor, Int8Stochastic))

    def _gossip_hats(self, hat_new: PyTree, hat_old: PyTree, wire,
                     s: int, rnd, k_chan: Array) -> PyTree:
        if wire is not None and self._use_fused_hop():
            qs, scales, treedef = wire
            base = self.backend.mix_hop(self.gossip, hat_old) \
                if self.comm.error_feedback else None

            def hop(q: Array, scale: Array, like: Array) -> Array:
                n = q.shape[0]
                out = self.backend.quant_ring_hop(
                    self.gossip, q.reshape(n, -1), scale.reshape(n, 1),
                    out_dtype=like.dtype)
                return out.reshape(like.shape)

            leaves_old = jax.tree.leaves(hat_old)
            wire_mix = jax.tree.unflatten(
                treedef, [hop(q, sc, l)
                          for q, sc, l in zip(qs, scales, leaves_old)])
            first = (jax.tree.map(lambda b, w: b + w, base, wire_mix)
                     if base is not None else wire_mix)
            if s <= 1:
                return first
            if self.comm.quant_hops == "all":
                # tail hops stay on the int8 wire: every hop requantizes
                # deterministically (the shard_map backend fuses the whole
                # chain into one multi_hop_mix_quant launch per leaf)
                return jax.tree.map(
                    lambda l: self.backend.quant_ring_hops(
                        self.gossip, l, s - 1, out_dtype=l.dtype),
                    first)
            return self.backend.mix(self.gossip, first, steps=s - 1)
        return self.backend.mix_channel(self.gossip, self.channel, hat_new,
                                        rnd, k_chan, steps=s)


# ---------------------------------------------------------------------------
# optimizer shims
# ---------------------------------------------------------------------------


def maybe_engine(gossip,
                 backend: Optional[MixBackend] = None) -> Optional[CommEngine]:
    elastic = getattr(gossip, "elastic", None)
    if elastic is not None and elastic.enabled:
        # lazy: elastic.py imports this module at its top level
        from repro.comms.elastic import ElasticEngine
        return ElasticEngine(gossip, backend=backend)
    comm = getattr(gossip, "comm", None)
    if comm is not None and comm.enabled:
        return CommEngine(gossip, backend=backend)
    return None


def maybe_init_state(engine: Optional[CommEngine],
                     slots: dict[str, PyTree]) -> Optional[CommState]:
    return engine.init_state(slots) if engine is not None else None


def make_mixer(gossip, engine: Optional[CommEngine],
               comm_state: Optional[CommState], rnd: Array | int,
               backend: Optional[MixBackend] = None
               ) -> tuple[Callable[[str, PyTree, int], PyTree],
                          Callable[[], Optional[CommState]]]:
    """Slot-keyed mix router for one optimizer step.

    Returns ``(mix, finalize)``: ``mix(slot, tree, steps)`` routes through
    the comms engine when one is configured (threading the CommState) and
    through the exact path otherwise; ``finalize()`` yields the CommState to
    store in the next optimizer state.  ``backend`` overrides how exact hops
    execute (an engine carries its own backend); default is the gossip
    spec's resolved backend.
    """
    box = {"cs": comm_state}
    exact = backend if backend is not None else resolve_backend(gossip)

    def mix(slot: str, tree: PyTree, steps: int) -> PyTree:
        if engine is None:
            return exact.mix(gossip, tree, steps)
        out, box["cs"] = engine.mix(box["cs"], slot, tree,
                                    steps=steps, rnd=rnd)
        return out

    return mix, lambda: box["cs"]
