"""Communication subsystem: compression, error feedback, channel faults.

Everything between the optimizer and the wire — see :mod:`repro.comms.layer`
for the CHOCO-style engine, :mod:`repro.comms.compress` for the operators,
and :mod:`repro.comms.channel` for the fault/topology-schedule model.
"""
from repro.comms.api import (BACKENDS, CommLike, ElasticLike,  # noqa: F401
                             MixBackendProtocol, backend_names,
                             register_backend)
from repro.comms.backend import (MixBackend, ShardMapBackend,  # noqa: F401
                                 StackedBackend, make_backend,
                                 resolve_backend)
from repro.comms.channel import ChannelModel  # noqa: F401
from repro.comms.elastic import (ChurnSchedule, ElasticEngine,  # noqa: F401
                                 ElasticSpec, Membership)
from repro.comms.compress import (Compressor, IdentityCompressor,  # noqa: F401
                                  Int8Stochastic, LowRank, TopK,
                                  make_compressor, tree_bits,
                                  tree_param_count)
from repro.comms.layer import (CommEngine, CommState, make_mixer,  # noqa: F401
                               maybe_engine, maybe_init_state)
from repro.comms.spec import CommSpec  # noqa: F401
