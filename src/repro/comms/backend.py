"""Pluggable mix backends: how one gossip hop actually executes.

Every layer above this module (``GossipSpec.mix``, ``CommEngine``, the
optimizers) describes *what* to mix — ``x_i <- [W x]_i`` over node-stacked
pytrees.  A :class:`MixBackend` decides *how*:

* :class:`StackedBackend` — the node axis is leaf axis 0 of every array on
  every device.  One hop is ``jnp.roll``/dense einsum over that axis, exactly
  the semantics the repo has always had; XLA may or may not lower the roll to
  a ``collective-permute`` depending on sharding.  Default on CPU and in
  tests; bit-exact reference for the others.
* :class:`ShardMapBackend` — the node axis is a *device mesh axis*.  Leaves
  are ``shard_map``-ped over it, so each device holds a contiguous block of
  ``b = n_nodes / axis_size`` node rows, and one ring hop exchanges only the
  two *edge rows* of each block via ``jax.lax.ppermute`` (int8 payloads for
  the fused compressed hop), followed by the local ``ring_mix`` combine —
  the Pallas ``ring_mix_flat`` kernel on TPU, its jnp oracle elsewhere.  The
  k>1 schedule is double-buffered: hop ``t+1``'s edge rows are computed first
  and put on the wire while hop ``t``'s interior rows combine, so the permute
  latency hides behind the elementwise work.  ``ChannelModel`` faults become
  per-link weight vectors (three diagonals of ``W_t``) applied on the shard —
  never a dense ``(n, n)`` einsum against model-sized data.

Per-row arithmetic is kept *expression-identical* between the two backends
(``wc * x_i + ws * (x_{i-1} + x_{i+1})`` for the ring, the same full-shape
einsum for dense topologies), so a clean-channel fp32 mix is bit-identical
across backends — ``tests/test_mix_backend_equiv.py`` asserts exactly that
under 8 forced host devices.

Topology matrices stay in :mod:`repro.core.gossip` as the spectral-gap
oracle; backends only consume ``spec.matrix`` / ``spec.self_weight``.

NOTE: ``repro.core.gossip`` is imported lazily inside methods — the comms
package must stay import-independent of ``repro.core`` (same convention as
``channel.py``).
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.comms import api

Array = jax.Array
PyTree = Any

_FWD = 1   # ring direction conventions: row i's left neighbour is i-1
_BWD = -1


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class MixBackend(Protocol):
    """Strategy interface between the gossip math and the wire.

    All methods take the ``GossipSpec`` explicitly so one backend object
    (which may hold a device mesh) can serve any number of specs.
    """

    name: str

    def mix(self, spec, tree: PyTree, steps: int) -> PyTree:
        """Exact ``x <- W^steps x`` over a node-stacked pytree."""
        ...

    def mix_hop(self, spec, tree: PyTree) -> PyTree:
        """One exact ``W`` hop (``mix`` with ``steps=1``)."""
        ...

    def mix_channel(self, spec, channel, tree: PyTree, rnd, key: Array,
                    steps: int) -> PyTree:
        """``steps`` hops through a :class:`repro.comms.channel.ChannelModel`
        (link drops / stragglers / schedules)."""
        ...

    def mix_wt(self, spec, tree: PyTree, wt: Array, *,
               steps: int = 1) -> PyTree:
        """``steps`` hops of one explicit effective mixing matrix ``wt``
        (n, n) — the elastic engine's realized W_t, shared across the hops
        of a round.  Per-row math must match ``mix_channel``'s faulty-round
        expression so an elastic round degenerates bit-for-bit to the
        channel path when the realized matrices coincide."""
        ...

    def quant_ring_hop(self, spec, q: Array, scale: Array, *,
                       out_dtype=jnp.float32) -> Array:
        """Fused compressed ring hop on an int8 payload ``q`` (n, F) with
        per-node scales (n, 1): ``wc*dq(q_i) + ws*(dq(q_{i-1}) + dq(q_{i+1}))``.
        Only the int8 bytes travel."""
        ...

    def quant_ring_hops(self, spec, x: Array, steps: int, *,
                        out_dtype=None) -> Array:
        """``steps`` ring hops on one node-stacked leaf where EVERY hop is
        int8-compressed: each hop deterministically requantizes its input
        (round-to-nearest, per-node max-abs/127 scale) and combines the
        dequantized values — so only int8 bytes (+ one f32 scale per row)
        ever need to travel.  The requantization is part of the *math*, not
        the layout: every backend decodes identical int8 values at every
        hop, so results agree across backends to float-contraction (FMA)
        rounding of the final combines — a few ulps."""
        ...

    def est_hop_bytes(self, spec, tree: PyTree) -> float:
        """Estimated bytes moved device-to-device by one exact hop."""
        ...

    def est_quant_hop_bytes(self, spec, tree: PyTree) -> float:
        """Estimated bytes moved by one int8-compressed hop of the
        ``quant_ring_hops`` schedule (int8 payload + f32 scale per row)."""
        ...


# ---------------------------------------------------------------------------
# stacked (reference) backend
# ---------------------------------------------------------------------------


class StackedBackend:
    """Node axis = leaf axis 0 everywhere; the repo's original exact paths."""

    name = "stacked"

    def mix(self, spec, tree: PyTree, steps: int) -> PyTree:
        from repro.core import gossip as G
        if spec.n_nodes == 1 or steps == 0:
            return tree
        if spec.topology == "ring":
            return G.mix_ring(tree, steps=steps, self_weight=spec.self_weight)
        # W^s built ONCE per call (in float64 numpy, so it constant-folds
        # under jit), not per leaf inside the tree map.
        ws = dense_power(spec, steps)
        return jax.tree.map(
            lambda x: jnp.einsum("ij,j...->i...", ws.astype(x.dtype), x), tree)

    def mix_hop(self, spec, tree: PyTree) -> PyTree:
        return self.mix(spec, tree, steps=1)

    def mix_channel(self, spec, channel, tree: PyTree, rnd, key: Array,
                    steps: int) -> PyTree:
        return channel.mix(tree, rnd, key, steps=steps)

    def mix_wt(self, spec, tree: PyTree, wt: Array, *,
               steps: int = 1) -> PyTree:
        # the identical einsum expression ChannelModel.mix_hop applies to a
        # faulty round, so elastic W_t application is bit-equal to the
        # channel path whenever the matrices are bit-equal
        for _ in range(max(steps, 0)):
            tree = jax.tree.map(
                lambda x: jnp.einsum("ij,j...->i...", wt.astype(x.dtype), x),
                tree)
        return tree

    def quant_ring_hop(self, spec, q: Array, scale: Array, *,
                       out_dtype=jnp.float32) -> Array:
        from repro.kernels import ops
        wc = spec.self_weight
        ws = (1.0 - wc) / 2.0
        return ops.quant_mix(
            q, jnp.roll(q, 1, 0), jnp.roll(q, -1, 0),
            scale, jnp.roll(scale, 1, 0), jnp.roll(scale, -1, 0),
            w_self=wc, w_side=ws, out_dtype=out_dtype)

    def quant_ring_hops(self, spec, x: Array, steps: int, *,
                        out_dtype=None) -> Array:
        """Every hop requantizes deterministically and combines the decoded
        values — the layout-independent oracle of the all-hop compressed
        ``W^k`` schedule (what the shard_map megakernel fuses)."""
        from repro.comms.compress import quantize_det
        out_dtype = x.dtype if out_dtype is None else out_dtype
        n = x.shape[0]
        z = x
        for _ in range(max(steps, 0)):
            q, s = quantize_det(z)
            z = self.quant_ring_hop(
                spec, q.reshape(n, -1), s.reshape(n, 1),
                out_dtype=jnp.float32).reshape(x.shape)
        return z.astype(out_dtype)

    def est_hop_bytes(self, spec, tree: PyTree) -> float:
        total = _tree_bytes(tree)
        if spec.topology == "ring":
            # roll moves every node row one slot in each direction
            return 2.0 * total
        # dense einsum over a sharded node axis lowers to an all-gather:
        # every node row reaches every other node
        return float(spec.n_nodes - 1) * total

    def est_quant_hop_bytes(self, spec, tree: PyTree) -> float:
        total = _quant_tree_bytes(tree)
        if spec.topology == "ring":
            return 2.0 * total
        return float(spec.n_nodes - 1) * total

    def __repr__(self):
        return "StackedBackend()"


# ---------------------------------------------------------------------------
# shard_map (SPMD) backend
# ---------------------------------------------------------------------------


class ShardMapBackend:
    """Node axis = device mesh axis; neighbour-only ``ppermute`` exchange.

    ``axis`` may be one mesh axis name or a tuple (e.g. ``("pod", "node")``
    for multi-pod rings — ``ppermute``/``axis_index`` accept the tuple and
    linearize it row-major, extending the gossip ring across pods).

    Falls back to the stacked paths when the factored axis has a single
    device or ``n_nodes < 3`` (degenerate rings have their own exact
    special cases which a neighbour exchange cannot reproduce bit-for-bit).
    """

    name = "shard_map"

    def __init__(self, mesh: Mesh, axis: str | Sequence[str] = "node",
                 fuse: str = "auto", fuse_depth: Optional[int] = None):
        if fuse not in ("auto", "on", "off"):
            raise ValueError(f"fuse must be auto|on|off, got {fuse!r}")
        self.mesh = mesh
        self.axes: tuple[str, ...] = (axis,) if isinstance(axis, str) \
            else tuple(axis)
        for a in self.axes:
            if a not in mesh.shape:
                raise ValueError(f"mesh {mesh.shape} has no axis {a!r}")
        self.axis_size = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.fuse = fuse
        # hops per megakernel launch (halo width == depth); None = all hops
        # in one launch.  Bounds the halo so a deep W^k schedule on a small
        # block doesn't drown the panel in halo rows.
        self.fuse_depth = fuse_depth
        # "auto" fuses everywhere launch latency dominates: always on TPU
        # (the kernel's target — k launches collapse to 1), but on the
        # CPU/GPU oracle paths only for small rows, where the 2*halo extra
        # panel rows cost less than the k-1 saved collective rounds
        self._fuse_on_big_rows = any(
            d.platform == "tpu" for d in mesh.devices.flat)
        self._stacked = StackedBackend()

    #: "auto" row-size cutoff on non-TPU backends (bytes per node row);
    #: above this the hop-by-hop schedule's smaller working set wins there
    AUTO_FUSE_MAX_ROW_BYTES = 1 << 20

    # -- helpers ------------------------------------------------------------

    @property
    def _axis_name(self):
        return self.axes if len(self.axes) > 1 else self.axes[0]

    @property
    def _pspec(self) -> P:
        return P(self.axes if len(self.axes) > 1 else self.axes[0])

    def _block(self, spec) -> int:
        n, d = spec.n_nodes, self.axis_size
        if n % d:
            raise ValueError(
                f"n_nodes={n} must divide over the {self.axes} mesh axes "
                f"(size {d}) for the shard_map backend")
        return n // d

    def _use_stacked(self, spec) -> bool:
        return self.axis_size == 1 or spec.n_nodes < 3

    def _shmap(self, fn, tree_specs, out_specs=None):
        return shard_map(fn, mesh=self.mesh, in_specs=tree_specs,
                         out_specs=out_specs if out_specs is not None
                         else self._pspec, check_rep=False)

    def _perm(self, direction: int):
        d = self.axis_size
        return [(i, (i + direction) % d) for i in range(d)]

    def _perm_shift(self, j: int):
        """Permutation under which every device receives from device i-j
        (send i -> i+j around the ring); ``_perm(d)`` generalized."""
        d = self.axis_size
        return [(i, (i + j) % d) for i in range(d)]

    def _gather_halo(self, x: Array, halo: int) -> Array:
        """Assemble the ``(halo + b + halo, ...)`` megakernel input panel.

        The halo of width ``halo`` on each side is fetched with
        ``ceil(halo/b)`` *independent* shift-j ppermutes per direction —
        they carry no data dependence on each other, so XLA can put all of
        them on the wire concurrently (vs. the unfused schedule's k strictly
        serialized edge exchanges).  Wire bytes are identical to k unfused
        hops: 2*halo rows per device either way.
        """
        ax = self._axis_name
        b = x.shape[0]
        m = -(-halo // b)                          # ppermute shifts per side
        top, bot = [], []
        for j in range(1, m + 1):
            cnt = min(b, halo - (j - 1) * b)       # rows still needed
            top.append(jax.lax.ppermute(x[-cnt:], ax, self._perm_shift(j)))
            bot.append(jax.lax.ppermute(x[:cnt], ax, self._perm_shift(-j)))
        # top pieces arrive nearest-neighbour first; the panel wants the
        # furthest rows first, so reverse.  Bottom pieces stack in order.
        return jnp.concatenate(top[::-1] + [x] + bot, axis=0)

    # -- exact ring hops ----------------------------------------------------

    def _ring_hops_block(self, x: Array, steps: int, wc: float,
                         ws: float) -> Array:
        """``steps`` ring hops on the local (b, ...) node block: one halo
        exchange + one fused megakernel (the fast path), or the hop-by-hop
        double-buffered schedule when ``fuse='off'`` — or when ``'auto'``
        decides the fusion doesn't pay on this backend/row size."""
        if self.fuse == "off" or steps <= 0:
            return self._ring_hops_block_unfused(x, steps, wc, ws)
        if self.fuse == "auto" and not self._fuse_on_big_rows:
            row_bytes = (x.size // x.shape[0]) * x.dtype.itemsize
            if row_bytes > self.AUTO_FUSE_MAX_ROW_BYTES:
                return self._ring_hops_block_unfused(x, steps, wc, ws)
        return self._ring_hops_block_fused(x, steps, wc, ws)

    def _ring_hops_block_fused(self, x: Array, steps: int, wc: float,
                               ws: float) -> Array:
        """Halo-panel fusion: gather a halo of width k, then ONE Pallas
        launch runs all k combines VMEM-resident.

        Rows beyond the halo see zeros instead of their true ring
        neighbours, so panel-end garbage advances exactly one row per hop —
        the center ``b`` rows are exact as long as ``halo >= hops`` (same
        invariant the kernel asserts).  Per-element math is the identical
        ``wc*z + ws*(l+r)`` expression, hence still bit-equal to the
        stacked path.  ``fuse_depth`` chunks a deep schedule into multiple
        launches of at most that many hops each.
        """
        from repro.kernels import ops
        shape = x.shape
        remaining = steps
        while remaining > 0:
            k = min(self.fuse_depth or remaining, remaining)
            panel = self._gather_halo(x, k)
            x = ops.multi_hop_mix(
                panel.reshape(panel.shape[0], -1), hops=k,
                out_rows=shape[0], halo=k, w_self=wc, w_side=ws,
            ).reshape(shape)
            remaining -= k
        return x

    def _ring_hops_block_unfused(self, x: Array, steps: int, wc: float,
                                 ws: float) -> Array:
        """``steps`` ring hops on the local (b, ...) node block.

        Per-row math is ``wc*x_i + ws*(x_{i-1} + x_{i+1})`` — expression-
        identical to the stacked ``mix_ring`` leaf, so fp32 results are
        bit-equal.  Double buffering: each hop combines its two edge rows
        FIRST and launches their ppermute for hop ``t+1`` before the
        interior combine, so the wire transfer of the next hop overlaps the
        local elementwise work of the current one.
        """
        from repro.kernels import ops
        ax = self._axis_name
        b = x.shape[0]
        # prologue: hop 0's edge exchange
        prev_last = jax.lax.ppermute(x[-1:], ax, self._perm(_FWD))
        next_first = jax.lax.ppermute(x[:1], ax, self._perm(_BWD))
        for t in range(steps):
            if b == 1:
                lo = hi = wc * x + ws * (prev_last + next_first)
            else:
                lo = wc * x[:1] + ws * (prev_last + x[1:2])
                hi = wc * x[-1:] + ws * (x[-2:-1] + next_first)
            if t + 1 < steps:
                # hop t+1's edges hit the wire while the interior combines
                prev_last = jax.lax.ppermute(hi, ax, self._perm(_FWD))
                next_first = jax.lax.ppermute(lo, ax, self._perm(_BWD))
            if b == 1:
                x = lo
            elif b == 2:
                x = jnp.concatenate([lo, hi], axis=0)
            else:
                inner = ops.ring_mix(x[1:-1], x[:-2], x[2:],
                                     w_self=wc, w_side=ws)
                x = jnp.concatenate([lo, inner, hi], axis=0)
        return x

    # -- gathered dense fallback (full / torus / star) ----------------------

    def _dense_block(self, x: Array, w: Array, b: int) -> Array:
        """All-gather the node axis and run the SAME full-shape einsum as the
        stacked path, then slice the local rows — dense topologies genuinely
        need every row, and reusing the identical contraction keeps the
        result bit-equal to :class:`StackedBackend`."""
        ax = self._axis_name
        xg = jax.lax.all_gather(x, ax, axis=0, tiled=True)      # (n, ...)
        mixed = jnp.einsum("ij,j...->i...", w.astype(xg.dtype), xg)
        return jax.lax.dynamic_slice_in_dim(
            mixed, self._linear_index() * b, b, axis=0)

    def _linear_index(self):
        idx = jax.lax.axis_index(self.axes[0])
        for a in self.axes[1:]:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    # -- MixBackend surface -------------------------------------------------

    def mix(self, spec, tree: PyTree, steps: int) -> PyTree:
        if spec.n_nodes == 1 or steps == 0:
            return tree
        if self._use_stacked(spec):
            return self._stacked.mix(spec, tree, steps)
        b = self._block(spec)
        if spec.topology == "ring":
            wc = spec.self_weight
            ws = (1.0 - wc) / 2.0

            def body(t):
                return jax.tree.map(
                    lambda x: self._ring_hops_block(x, steps, wc, ws), t)
        else:
            w = dense_power(spec, steps)

            def body(t):
                return jax.tree.map(lambda x: self._dense_block(x, w, b), t)

        specs = jax.tree.map(lambda _: self._pspec, tree)
        return self._shmap(body, (specs,), out_specs=specs)(tree)

    def mix_hop(self, spec, tree: PyTree) -> PyTree:
        return self.mix(spec, tree, steps=1)

    def mix_channel(self, spec, channel, tree: PyTree, rnd, key: Array,
                    steps: int) -> PyTree:
        if channel.trivial:
            return self.mix(spec, tree, steps)
        if self._use_stacked(spec):
            return self._stacked.mix_channel(spec, channel, tree, rnd, key,
                                             steps)
        if spec.topology != "ring":
            # dense fallback: same W_t sequence, full gathered contraction
            return self._mix_channel_dense(spec, channel, tree, rnd, key,
                                           steps)
        b = self._block(spec)
        x_specs = jax.tree.map(lambda _: self._pspec, tree)
        for h in range(steps):
            # identical W_t sampling schedule to ChannelModel.mix, but the
            # (n, n) matrix is consumed ONLY as its three ring diagonals:
            # per-link ppermute filtering, no dense einsum on model data.
            wd, wl, wr = channel.ring_link_weights(
                rnd * steps + h, jax.random.fold_in(key, h))
            tree = self._shmap(
                functools.partial(self._channel_ring_hop_blocks, b=b),
                (x_specs, self._pspec, self._pspec, self._pspec),
                out_specs=x_specs,
            )(tree, wd, wl, wr)
        return tree

    def _channel_ring_hop_blocks(self, tree, wd, wl, wr, *, b: int):
        ax = self._axis_name

        def one(x):
            prev_last = jax.lax.ppermute(x[-1:], ax, self._perm(_FWD))
            next_first = jax.lax.ppermute(x[:1], ax, self._perm(_BWD))
            if b == 1:
                left, right = prev_last, next_first
            else:
                left = jnp.concatenate([prev_last, x[:-1]], axis=0)
                right = jnp.concatenate([x[1:], next_first], axis=0)
            shape = (b,) + (1,) * (x.ndim - 1)
            wdx = wd.astype(x.dtype).reshape(shape)
            wlx = wl.astype(x.dtype).reshape(shape)
            wrx = wr.astype(x.dtype).reshape(shape)
            return wdx * x + wlx * left + wrx * right

        return jax.tree.map(one, tree)

    def _mix_channel_dense(self, spec, channel, tree, rnd, key, steps):
        b = self._block(spec)
        x_specs = jax.tree.map(lambda _: self._pspec, tree)
        for h in range(steps):
            wt = channel.w_t(rnd * steps + h, jax.random.fold_in(key, h))
            tree = self._shmap(
                lambda t, w: jax.tree.map(
                    lambda x: self._dense_block(x, w, b), t),
                (x_specs, P()), out_specs=x_specs)(tree, wt)
        return tree

    def mix_wt(self, spec, tree: PyTree, wt: Array, *,
               steps: int = 1) -> PyTree:
        """Explicit-W_t hops.  A realized elastic matrix over a ring stays
        ring-banded (it is the base ring matrix with links masked and the
        mass folded into the diagonal), so it is consumed as its three
        diagonals on the same per-link ``ring_link_weights`` path the
        channel model uses — never a dense (n, n) einsum against model
        data.  The fused ``multi_hop_mix`` megakernel path is untouched:
        clean static-membership mixes keep routing through it."""
        if steps <= 0 or spec.n_nodes == 1:
            return tree
        if self._use_stacked(spec):
            return self._stacked.mix_wt(spec, tree, wt, steps=steps)
        b = self._block(spec)
        x_specs = jax.tree.map(lambda _: self._pspec, tree)
        if spec.topology == "ring":
            n = spec.n_nodes
            i = jnp.arange(n)
            wd, wl, wr = wt[i, i], wt[i, (i - 1) % n], wt[i, (i + 1) % n]
            hop = self._shmap(
                functools.partial(self._channel_ring_hop_blocks, b=b),
                (x_specs, self._pspec, self._pspec, self._pspec),
                out_specs=x_specs)
            for _ in range(steps):
                tree = hop(tree, wd, wl, wr)
            return tree
        hop = self._shmap(
            lambda t, w: jax.tree.map(lambda x: self._dense_block(x, w, b), t),
            (x_specs, P()), out_specs=x_specs)
        for _ in range(steps):
            tree = hop(tree, wt)
        return tree

    def quant_ring_hop(self, spec, q: Array, scale: Array, *,
                       out_dtype=jnp.float32) -> Array:
        if self._use_stacked(spec):
            return self._stacked.quant_ring_hop(spec, q, scale,
                                                out_dtype=out_dtype)
        from repro.kernels import ops
        b = self._block(spec)
        wc = spec.self_weight
        ws = (1.0 - wc) / 2.0
        ax = self._axis_name

        def body(qb, sb):
            # only the int8 edge rows (+ one f32 scale each) travel: the
            # wire window is 4x smaller than a full-precision exchange
            ql_e = jax.lax.ppermute(qb[-1:], ax, self._perm(_FWD))
            sl_e = jax.lax.ppermute(sb[-1:], ax, self._perm(_FWD))
            qr_e = jax.lax.ppermute(qb[:1], ax, self._perm(_BWD))
            sr_e = jax.lax.ppermute(sb[:1], ax, self._perm(_BWD))
            if b == 1:
                ql, qr, sl, sr = ql_e, qr_e, sl_e, sr_e
            else:
                ql = jnp.concatenate([ql_e, qb[:-1]], axis=0)
                sl = jnp.concatenate([sl_e, sb[:-1]], axis=0)
                qr = jnp.concatenate([qb[1:], qr_e], axis=0)
                sr = jnp.concatenate([sb[1:], sr_e], axis=0)
            return ops.quant_mix(qb, ql, qr, sb, sl, sr, w_self=wc,
                                 w_side=ws, out_dtype=out_dtype)

        return self._shmap(body, (self._pspec, self._pspec))(q, scale)

    def quant_ring_hops(self, spec, x: Array, steps: int, *,
                        out_dtype=None) -> Array:
        """All-hop compressed ``W^k`` schedule.  Fused path: quantize the
        local block once, halo-exchange the *int8* panel (+ per-row scales),
        then one ``multi_hop_mix_quant`` launch replays every hop's
        dequant -> combine -> requant chain VMEM-resident.  The in-kernel
        requantization is the same deterministic formula the stacked oracle
        applies globally, so both paths decode identical int8 values and
        agree to FMA rounding of the combines."""
        if self._use_stacked(spec):
            return self._stacked.quant_ring_hops(spec, x, steps,
                                                 out_dtype=out_dtype)
        if steps <= 0:
            return x if out_dtype is None else x.astype(out_dtype)
        from repro.comms.compress import quantize_det
        from repro.kernels import ops
        out_dtype = x.dtype if out_dtype is None else out_dtype
        b = self._block(spec)
        wc = spec.self_weight
        ws = (1.0 - wc) / 2.0

        if self.fuse == "off":
            # hop-by-hop: global deterministic quantize, shard compressed hop
            z = x
            n = x.shape[0]
            for _ in range(steps):
                q, s = quantize_det(z)
                z = self.quant_ring_hop(
                    spec, q.reshape(n, -1), s.reshape(n, 1),
                    out_dtype=jnp.float32).reshape(x.shape)
            return z.astype(out_dtype)

        def body(xb):
            shape = xb.shape
            zb = xb
            remaining = steps
            while remaining > 0:
                k = min(self.fuse_depth or remaining, remaining)
                # quantize_det here IS the requant the kernel's next chunk
                # would have applied — chunking preserves the all-hop math
                qb, sb = quantize_det(zb.reshape(b, -1))
                zb = ops.multi_hop_mix_quant(
                    self._gather_halo(qb, k),
                    self._gather_halo(sb, k),
                    hops=k, out_rows=b, halo=k, w_self=wc, w_side=ws,
                    out_dtype=jnp.float32,
                ).reshape(shape)
                remaining -= k
            return zb.astype(out_dtype)

        return self._shmap(body, (self._pspec,))(x)

    def est_hop_bytes(self, spec, tree: PyTree) -> float:
        if self._use_stacked(spec):
            return self._stacked.est_hop_bytes(spec, tree)
        total = _tree_bytes(tree)
        row = total / max(spec.n_nodes, 1)
        if spec.topology == "ring":
            # two edge rows per device, both directions
            return 2.0 * self.axis_size * row
        return float(spec.n_nodes - 1) * total   # all-gather

    def est_quant_hop_bytes(self, spec, tree: PyTree) -> float:
        if self._use_stacked(spec):
            return self._stacked.est_quant_hop_bytes(spec, tree)
        total = _quant_tree_bytes(tree)
        row = total / max(spec.n_nodes, 1)
        if spec.topology == "ring":
            # halo exchange ships the same 2 rows/hop, just int8 + scale
            return 2.0 * self.axis_size * row
        return float(spec.n_nodes - 1) * total

    def __repr__(self):
        return (f"ShardMapBackend(axes={self.axes}, "
                f"axis_size={self.axis_size}, fuse={self.fuse!r})")


# ---------------------------------------------------------------------------
# shared helpers / registry
# ---------------------------------------------------------------------------


def dense_power(spec, steps: int) -> Array:
    """``W^steps`` as an f32 constant (float64 numpy power, so it constant-
    folds under jit) — the one dense-matrix artifact both backends share."""
    m = spec.matrix
    return jnp.asarray(np.linalg.matrix_power(m, steps) if steps > 1 else m,
                       dtype=jnp.float32)


def _tree_bytes(tree: PyTree) -> float:
    return float(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree)))


def _quant_tree_bytes(tree: PyTree) -> float:
    """Bytes of one int8-compressed copy: 1 B/element + one f32 scale per
    node row (leaf axis 0)."""
    return float(sum(l.size * 1 + l.shape[0] * 4
                     for l in jax.tree.leaves(tree)))


def resolve_backend(spec) -> MixBackend:
    """The backend a ``GossipSpec`` routes through (stacked when unset).

    ``spec.backend`` may be a backend instance or a registry name
    (``"stacked" | "shard_map"``, see :data:`repro.comms.api.BACKENDS`)."""
    be = getattr(spec, "backend", None)
    if be is None:
        return _DEFAULT_STACKED
    if isinstance(be, str):
        return make_backend(be)
    return be


def make_backend(kind: str = "auto", *, mesh: Optional[Mesh] = None,
                 axis: str | Sequence[str] = "node", fuse: str = "auto",
                 fuse_depth: Optional[int] = None) -> MixBackend:
    """Config-knob constructor, dispatching through the
    :data:`repro.comms.api.BACKENDS` string registry.

    ``stacked`` — always the stacked backend.
    ``shard_map`` — requires a mesh with the node axis.
    ``auto`` — shard_map when a mesh with a >1-device node axis is given,
    stacked otherwise.
    ``fuse``/``fuse_depth`` configure the shard_map multi-hop megakernel
    (``auto``/``on`` = fused halo panels, ``off`` = hop-by-hop ppermute).
    """
    if kind == "auto":
        if mesh is not None:
            axes = (axis,) if isinstance(axis, str) else tuple(axis)
            if all(a in mesh.shape for a in axes) and \
                    int(np.prod([mesh.shape[a] for a in axes])) > 1:
                kind = "shard_map"
            else:
                kind = "stacked"
        else:
            kind = "stacked"
    factory = api.BACKENDS.get(kind)
    if factory is None:
        raise ValueError(
            f"unknown mix backend {kind!r}; registered: {api.backend_names()}")
    return factory(mesh=mesh, axis=axis, fuse=fuse, fuse_depth=fuse_depth)


_DEFAULT_STACKED = StackedBackend()


def _make_stacked(*, mesh=None, axis="node", fuse="auto",
                  fuse_depth=None) -> MixBackend:
    return _DEFAULT_STACKED


def _make_shard_map(*, mesh=None, axis="node", fuse="auto",
                    fuse_depth=None) -> MixBackend:
    if mesh is None:
        raise ValueError("mix_backend='shard_map' requires a mesh")
    return ShardMapBackend(mesh, axis=axis, fuse=fuse, fuse_depth=fuse_depth)


api.register_backend("stacked", _make_stacked)
api.register_backend("shard_map", _make_shard_map)
