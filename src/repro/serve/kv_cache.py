"""Paged KV cache: block-table-indexed pages from one fixed pool.

Contiguous per-request KV buffers waste memory on ragged workloads — a
4k-context slot and a 30-token slot cost the same.  Here every attention
layer owns one *pool* of ``(n_pages, page_size, Hkv, hd)`` pages; a decode
slot references its pages through a row of the shared block table
``(n_slots, max_pages_per_slot)`` int32.  Unallocated entries are ``-1``;
page 0 is the *dump page* — a write/read sink for inactive slots, never
handed out by the allocator — so the fused decode step needs no host-side
branching on slot liveness (``kernels/paged_decode.py`` clamps ``-1`` to 0
and fully masks those positions).

The pool pytree mirrors ``models.transformer.init_cache``'s stage/block
structure (a leading ``repeat`` axis for scanned stages) with only
``{"k_pages", "v_pages"}`` leaves, so it threads through ``apply_stage``'s
scan machinery unchanged; :class:`PagePool` is the host-side allocator
(free list + admission reservations) the scheduler draws from.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PagedKVSpec:
    """Static geometry of the paged cache."""
    page_size: int = 16          # tokens per page
    n_pages: int = 64            # pool size per attention layer (incl. dump)
    max_pages_per_slot: int = 8  # block-table width M

    def __post_init__(self):
        assert self.page_size >= 1 and self.n_pages >= 2, self
        assert self.max_pages_per_slot >= 1, self

    @property
    def max_context(self) -> int:
        """Longest sequence one slot can hold (prompt + generated)."""
        return self.page_size * self.max_pages_per_slot

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)


class PagePool:
    """Host-side page allocator: free list over pages ``1..n_pages-1``.

    Admission *reserves* a request's worst-case page count up front (so a
    request never deadlocks mid-decode waiting for pages), then draws its
    actual pages from the reservation.  Page 0 (the dump page) is never
    allocated."""

    def __init__(self, spec: PagedKVSpec):
        self.spec = spec
        self._free = list(range(spec.n_pages - 1, 0, -1))  # pop() -> low ids

    @property
    def n_free(self) -> int:
        return len(self._free)

    def can_reserve(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` pages; raises if the pool is exhausted (callers gate
        on :meth:`can_reserve` at admission, so this is a logic error)."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        return [self._free.pop() for _ in range(n)]

    def release(self, pages: list[int]) -> None:
        for p in pages:
            assert 0 < p < self.spec.n_pages, p
            self._free.append(p)


# ---------------------------------------------------------------------------
# device-side pool pytree
# ---------------------------------------------------------------------------


def _is_paged_block(spec) -> bool:
    return spec.kind in ("attn", "moe_attn") and spec.attn.kind != "mla"


def validate_config(cfg: ModelConfig) -> None:
    """The paged path covers GQA attention blocks without sliding windows
    (full-context pages; the kernel's ``window`` masking is exercised at the
    kernel level).  Reject anything else up front."""
    for st in cfg.stages:
        for sp in st.blocks:
            if not _is_paged_block(sp):
                raise ValueError(
                    f"paged serving supports GQA attention blocks only, "
                    f"got kind={sp.kind!r}")
            if sp.attn.sliding_window is not None:
                raise ValueError(
                    "paged serving does not support sliding-window layers")
            if sp.attn.cross_attn:
                raise ValueError(
                    "paged serving does not support cross-attention layers")


def init_pools(cfg: ModelConfig, spec: PagedKVSpec,
               dtype=jnp.float32) -> dict:
    """Zero-filled per-layer page pools, shaped like ``init_cache``'s tree
    (scanned stages carry the leading ``repeat`` axis)."""
    pools = {}
    for i, st in enumerate(cfg.stages):
        cell = {}
        for j, sp in enumerate(st.blocks):
            shape = (spec.n_pages, spec.page_size, cfg.n_kv_heads, cfg.hd)
            cell[f"b{j}"] = {
                "k_pages": jnp.zeros(shape, dtype),
                "v_pages": jnp.zeros(shape, dtype),
            }
        if st.repeat > 1:
            cell = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (st.repeat, *x.shape)),
                cell)
        pools[f"s{i}"] = cell
    return pools


def scatter_prompt(pools: dict, caches: dict, pages: Array, *,
                   cfg: ModelConfig, page_size: int) -> dict:
    """Copy one prompt's contiguous prefill caches into its pages.

    ``caches`` is ``forward(mode="prefill")``'s output for a batch-of-one
    prompt with ``cache_len`` >= ``len(pages) * page_size`` (so the ring
    buffer is position-ordered); ``pages`` is the slot's page ids, (np,)
    int32.  Jit this with ``donate_argnums=(0,)`` so pool updates are
    in-place."""
    npg = pages.shape[0]
    span = npg * page_size

    def put(pool: Array, rows: Array) -> Array:
        # rows (cl, Hkv, hd) -> (np, ps, Hkv, hd) page-major
        seq = rows[:span].reshape(npg, page_size, *rows.shape[1:])
        return pool.at[pages].set(seq)

    out = {}
    for i, st in enumerate(cfg.stages):
        cell = {}
        for j, _ in enumerate(st.blocks):
            c = caches[f"s{i}"][f"b{j}"]
            p = pools[f"s{i}"][f"b{j}"]
            if st.repeat > 1:       # (R, 1, cl, ...) caches / (R, P, ...) pool
                cell[f"b{j}"] = {
                    "k_pages": jax.vmap(put)(p["k_pages"], c["k"][:, 0]),
                    "v_pages": jax.vmap(put)(p["v_pages"], c["v"][:, 0]),
                }
            else:                   # (1, cl, ...) caches / (P, ...) pool
                cell[f"b{j}"] = {
                    "k_pages": put(p["k_pages"], c["k"][0]),
                    "v_pages": put(p["v_pages"], c["v"][0]),
                }
        out[f"s{i}"] = cell
    return out
