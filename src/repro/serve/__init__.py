"""repro.serve: decentralized decode service.

Continuous batching (``scheduler``), paged KV cache + block-table decode
kernel (``kv_cache`` + ``kernels/paged_decode.py``), the fused jitted step
(``engine``), and EF-int8 gossip weight-sync across replicas (``replica``).
"""
from repro.serve.engine import ServeEngine, serve_requests
from repro.serve.kv_cache import PagePool, PagedKVSpec, init_pools
from repro.serve.replica import ReplicaGroup
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

__all__ = [
    "ContinuousBatchingScheduler", "PagePool", "PagedKVSpec", "ReplicaGroup",
    "Request", "ServeEngine", "init_pools", "serve_requests",
]
