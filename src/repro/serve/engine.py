"""Decode engine: one jitted fused step over paged KV pools.

The step is {embed slot tokens, paged flash-attention decode through every
layer, sample, scatter new K/V into pages} — a single ``jax.jit`` with the
pools donated, so steady-state decode is one dispatch per token wave
regardless of how many slots are live.  Slot liveness never reaches the
device: inactive slots carry an all ``-1`` block-table row, their writes
land on the dump page and their sampled tokens are ignored host-side.

Prefill runs through ``models.transformer.forward(mode="prefill")`` per
admitted request, bucketed to whole pages (``ceil(len/page_size)`` pages →
one retrace per distinct page count, not per length; right-padding is safe
because causal masking keeps pad positions out of the sampled logits and
only the first ``len`` cache rows are scattered into pages).

:func:`serve_requests` is the reference serving loop wiring this engine to
a :class:`~repro.serve.scheduler.ContinuousBatchingScheduler`.
"""
from __future__ import annotations

import contextlib
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.serve import kv_cache
from repro.serve.kv_cache import PagedKVSpec
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

Array = jax.Array


def _sample(logits: Array, key: Array, temperature: float) -> Array:
    if temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1) \
            .astype(jnp.int32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


class ServeEngine:
    """Device state (pools, block table, slot tokens) + the jitted step."""

    def __init__(self, cfg: ModelConfig, params, *,
                 kv_spec: Optional[PagedKVSpec] = None, n_slots: int = 4,
                 temperature: float = 0.0, seed: int = 0, telemetry=None):
        kv_cache.validate_config(cfg)
        self.cfg = cfg
        self.params = params
        self.spec = kv_spec or PagedKVSpec()
        self.n_slots = n_slots
        self.temperature = float(temperature)
        self.telemetry = telemetry
        self._key = jax.random.PRNGKey(seed)
        dtype = params["embed"].dtype
        self.pools = kv_cache.init_pools(cfg, self.spec, dtype)
        m = self.spec.max_pages_per_slot
        self._bt = np.full((n_slots, m), -1, np.int32)
        self._positions = np.zeros((n_slots,), np.int32)
        self._tokens = np.zeros((n_slots,), np.int32)
        self._active = np.zeros((n_slots,), bool)
        self._prefill_fns: dict[int, object] = {}
        self._scatter = jax.jit(
            functools.partial(kv_cache.scatter_prompt, cfg=cfg,
                              page_size=self.spec.page_size),
            donate_argnums=(0,))
        self._step = jax.jit(self._step_impl, donate_argnums=(4,))
        self.steps_run = 0
        self.tokens_generated = 0

    # -- jitted bodies ------------------------------------------------------

    def _step_impl(self, params, tok, positions, bt, pools, key):
        logits, new_pools = transformer.decode_step(
            params, self.cfg, tok, (positions, bt), pools)
        return _sample(logits, key, self.temperature), new_pools

    def _prefill_fn(self, cache_len: int):
        fn = self._prefill_fns.get(cache_len)
        if fn is None:
            def body(params, tokens, last):
                logits, _, caches = transformer.forward(
                    params, self.cfg, tokens, mode="prefill",
                    cache_len=cache_len)
                return logits[0, last], caches
            fn = self._prefill_fns[cache_len] = jax.jit(body)
        return fn

    def _span(self, name: str):
        if self.telemetry is None:
            return contextlib.nullcontext()
        return self.telemetry.span(name)

    # -- slot lifecycle -----------------------------------------------------

    def admit(self, slot: int, prompt: list[int],
              pages: list[int]) -> int:
        """Prefill ``prompt`` into ``pages`` (the slot's full reservation)
        and return the first sampled token."""
        ps = self.spec.page_size
        length = len(prompt)
        assert 0 < length and not self._active[slot], (slot, length)
        npg = self.spec.pages_for(length)
        assert len(pages) >= npg, (len(pages), npg)
        cache_len = npg * ps

        tokens = np.zeros((1, cache_len), np.int32)
        tokens[0, :length] = prompt
        with self._span("serve.prefill"):
            last_logits, caches = self._prefill_fn(cache_len)(
                self.params, jnp.asarray(tokens),
                jnp.asarray(length - 1, jnp.int32))
            self._key, k = jax.random.split(self._key)
            first = int(_sample(last_logits[None], k,
                                self.temperature)[0])
            self.pools = self._scatter(
                self.pools, caches, jnp.asarray(pages[:npg], jnp.int32))

        self._bt[slot] = -1
        self._bt[slot, :len(pages)] = pages
        self._positions[slot] = length
        self._tokens[slot] = first
        self._active[slot] = True
        self.tokens_generated += 1
        if self.telemetry is not None:
            self.telemetry.event("serve", {
                "kind": "admit", "slot": slot, "prompt_len": length,
                "pages": len(pages)})
        return first

    def release(self, slot: int) -> None:
        self._bt[slot] = -1
        self._positions[slot] = 0
        self._tokens[slot] = 0
        self._active[slot] = False

    # -- the decode wave ----------------------------------------------------

    def step(self) -> np.ndarray:
        """One fused decode step for every slot; returns the (n_slots,)
        sampled tokens (garbage at inactive slots — callers consult the
        scheduler for liveness)."""
        self._key, k = jax.random.split(self._key)
        with self._span("serve.step"):
            nxt, self.pools = self._step(
                self.params, jnp.asarray(self._tokens),
                jnp.asarray(self._positions), jnp.asarray(self._bt),
                self.pools, k)
            nxt = np.asarray(nxt)
        act = self._active
        self._tokens[act] = nxt[act]
        self._positions[act] += 1
        self.steps_run += 1
        self.tokens_generated += int(act.sum())
        return nxt


def serve_requests(engine: ServeEngine,
                   sched: ContinuousBatchingScheduler,
                   requests: list[Request], *,
                   clock=None, idle_sleep: float = 1e-4) -> list[Request]:
    """Drive the engine until every request finishes.

    ``clock`` defaults to ``time.monotonic``; request ``arrival`` fields are
    offsets from the loop's start on that clock."""
    clock = clock or time.monotonic
    t0 = clock()
    now = lambda: clock() - t0
    for r in sorted(requests, key=lambda r: r.arrival):
        sched.submit(r)

    while not sched.idle:
        for slot, req in sched.admit(now()):
            first = engine.admit(slot, req.prompt, sched.slots[slot].pages)
            if sched.on_token(slot, first, now()) is not None:
                engine.release(slot)
        if sched.n_active == 0:
            time.sleep(idle_sleep)      # waiting on future arrivals
            continue
        toks = engine.step()
        t = now()
        for slot in sched.active_slots():
            if sched.on_token(slot, int(toks[slot]), t) is not None:
                engine.release(slot)
    return sched.finished
