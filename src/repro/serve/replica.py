"""Replica groups: gossip weight-sync across serving replicas.

N serving replicas hold independently-drifting copies of the weights (think
per-replica fine-tuning, LoRA merges, or straggling checkpoint pulls) and
periodically reconcile through the *training* stack's communication layer:
EF-int8 CHOCO gossip over the ring backend (``comms.layer.CommEngine`` with
``quant_hops="all"``, so the fused multi-hop megakernel path is what serving
exercises too).  Sync never blocks decode — it is a background pass over a
node-stacked copy of the parameters.

Consistency is quantified exactly like training consensus: the M_t-style
drift ``mean_i ||x_i - x̄|| / ||x̄||`` (consensus residual), emitted as
``replica`` telemetry events along with the wire-byte counters, so the obs
report can show how stale a replica is allowed to get between syncs.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.comms.layer import CommEngine
from repro.comms.spec import CommSpec
from repro.core.gossip import GossipSpec
from repro.obs import wire

SLOT = "serve"


class ReplicaGroup:
    """Node-stacked replica weights + one CommEngine sync path."""

    def __init__(self, params, n_replicas: int, *, gamma: float = 0.9,
                 k_steps: int = 2, quant_hops: str = "all",
                 seed: int = 0, telemetry=None):
        assert n_replicas >= 2, n_replicas
        self.n_replicas = n_replicas
        self.telemetry = telemetry
        comm = CommSpec(compressor="int8", error_feedback=True,
                        gamma=gamma, quant_hops=quant_hops, seed=seed)
        self.gossip = GossipSpec(topology="ring", n_nodes=n_replicas,
                                 k_steps=k_steps, comm=comm)
        self.engine = CommEngine(self.gossip)
        # strong-cast while stacking: jnp.stack preserves weak_type, and a
        # weak leaf here gives the jitted sync/step functions different
        # input avals on call one vs two — a silent mid-serve recompile
        # (the same bug class the optimizer inits strip with _strong)
        self.params = jax.tree.map(
            lambda x: jnp.stack([jnp.asarray(x)] * n_replicas)
            .astype(jnp.asarray(x).dtype), params)
        self.state = self.engine.init_state({SLOT: self.params})
        self.counters = wire.zero_counters()
        self._key = jax.random.PRNGKey(seed + 1)
        self._rnd = 0

    def replica(self, i: int):
        """Replica ``i``'s parameter tree (for a ServeEngine)."""
        return jax.tree.map(lambda x: x[i], self.params)

    def drift(self) -> float:
        """Consensus residual: ``mean_i ||x_i - x̄|| / ||x̄||``."""
        num = jnp.zeros((self.n_replicas,), jnp.float32)
        den = jnp.zeros((), jnp.float32)
        for leaf in jax.tree.leaves(self.params):
            mean = leaf.mean(axis=0)
            d = (leaf - mean).astype(jnp.float32)
            num = num + (d * d).sum(axis=tuple(range(1, leaf.ndim)))
            den = den + (mean.astype(jnp.float32) ** 2).sum()
        return float(jnp.sqrt(num).mean() / jnp.maximum(jnp.sqrt(den), 1e-12))

    def perturb(self, scale: float) -> float:
        """Add independent per-replica Gaussian drift (simulating divergent
        local updates); returns the resulting consensus residual."""
        self._key, k = jax.random.split(self._key)
        leaves, treedef = jax.tree.flatten(self.params)
        out = []
        for i, leaf in enumerate(leaves):
            noise = jax.random.normal(jax.random.fold_in(k, i), leaf.shape,
                                      jnp.float32) * scale
            out.append(leaf + noise.astype(leaf.dtype))
        self.params = jax.tree.unflatten(treedef, out)
        return self.drift()

    def sync(self, rounds: int = 1) -> list[float]:
        """Run ``rounds`` EF-int8 gossip rounds (``k_steps`` hops each);
        returns the drift after each round and emits ``replica`` events."""
        trace = []
        steps = self.gossip.k
        for _ in range(rounds):
            before = self.drift()
            mixed, self.state = self.engine.mix(
                self.state, SLOT, self.params, steps=steps, rnd=self._rnd)
            self.counters = wire.account_mix(
                self.counters, self.gossip, self.engine, self.engine.backend,
                self.state, SLOT, self.params, steps, self._rnd)
            self.params = mixed
            self._rnd += 1
            after = self.drift()
            trace.append(after)
            if self.telemetry is not None:
                c = wire.unpack(self.counters).as_dict()
                self.telemetry.event("replica", {
                    "round": self._rnd, "steps": steps,
                    "drift_before": before, "drift_after": after,
                    **c})
        return trace

    def wire_stats(self) -> dict:
        return wire.unpack(self.counters).as_dict()
