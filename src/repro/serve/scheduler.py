"""Continuous-batching scheduler: admission, eviction, refill.

Pure host-side bookkeeping — no jax.  Requests queue with arrival
timestamps; :meth:`ContinuousBatchingScheduler.admit` moves them into free
decode slots as soon as the page pool can cover their worst case
(``ceil((len(prompt) + max_new_tokens) / page_size)`` pages, allocated up
front so a request never stalls mid-decode).  On EOS or the token budget
the slot is released and refilled on the next ``admit`` — the batch never
drains to run a single straggler.

``refill="static"`` is the ablation baseline: a wave of requests is
admitted only when *every* slot is free, and nothing refills until the
whole wave finishes — classic static batching, where the longest request
holds the batch hostage.  ``benchmarks/serve.py`` races the two modes.
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import deque
from typing import Literal, Optional

from repro.serve.kv_cache import PagePool, PagedKVSpec

_RID = itertools.count()


@dataclasses.dataclass
class Request:
    """One decode request plus its lifecycle timestamps (seconds, on
    whatever clock the caller passes as ``now``)."""
    prompt: list[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    arrival: float = 0.0
    rid: int = dataclasses.field(default_factory=lambda: next(_RID))
    # filled in by the scheduler / engine
    tokens: list[int] = dataclasses.field(default_factory=list)
    t_admitted: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def latency(self) -> Optional[float]:
        return None if self.t_done is None else self.t_done - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (queue wait + prefill)."""
        return None if self.t_first_token is None \
            else self.t_first_token - self.arrival


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    pages: list[int] = dataclasses.field(default_factory=list)

    @property
    def free(self) -> bool:
        return self.request is None


class ContinuousBatchingScheduler:
    """Admission/eviction over ``n_slots`` decode slots and one page pool."""

    def __init__(self, n_slots: int, spec: PagedKVSpec, *,
                 refill: Literal["continuous", "static"] = "continuous"):
        self.spec = spec
        self.pool = PagePool(spec)
        self.refill = refill
        self.slots = [_Slot() for _ in range(n_slots)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []

    # -- state views --------------------------------------------------------

    @property
    def n_active(self) -> int:
        return sum(not s.free for s in self.slots)

    @property
    def idle(self) -> bool:
        return self.n_active == 0 and not self.queue

    def active_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if not s.free]

    # -- lifecycle ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.spec.max_context:
            raise ValueError(
                f"request {req.rid}: {len(req.prompt)}+{req.max_new_tokens} "
                f"tokens exceeds max_context={self.spec.max_context}")
        self.queue.append(req)

    def admit(self, now: float) -> list[tuple[int, Request]]:
        """Admit queued requests into free slots while pages last.

        Returns ``[(slot, request), ...]`` — the engine prefills each one.
        Static refill only admits into a fully-drained batch."""
        if self.refill == "static" and self.n_active > 0:
            return []
        admitted = []
        for i, slot in enumerate(self.slots):
            if not slot.free or not self.queue:
                continue
            req = self.queue[0]
            if req.arrival > now:
                break               # FIFO in arrival order
            need = self.spec.pages_for(len(req.prompt) + req.max_new_tokens)
            if not self.pool.can_reserve(need):
                break               # FIFO: don't starve the head request
            self.queue.popleft()
            slot.request = req
            slot.pages = self.pool.alloc(need)
            req.t_admitted = now
            admitted.append((i, req))
        return admitted

    def on_token(self, slot_idx: int, token: int,
                 now: float) -> Optional[Request]:
        """Record one generated token; evict + return the request when it
        hits EOS or its token budget, else None."""
        slot = self.slots[slot_idx]
        req = slot.request
        assert req is not None, f"token for free slot {slot_idx}"
        if req.t_first_token is None:
            req.t_first_token = now
        req.tokens.append(token)
        hit_eos = req.eos_id is not None and token == req.eos_id
        if hit_eos or len(req.tokens) >= req.max_new_tokens:
            req.t_done = now
            self.pool.release(slot.pages)
            slot.request = None
            slot.pages = []
            self.finished.append(req)
            return req
        return None
