"""Registered analysis entry points: the repo's lintable surfaces.

Each pass builds the smallest real instance of a subsystem (every optimizer
on a 4-node ring over a tiny Stiefel minimax problem, the smoke serve
config, the mix backends) and runs the relevant rules over it.  The CLI
(``python -m repro.analysis``) and the CI ``analysis`` job both consume
:data:`PASSES`; ``--rules`` filters by the rule names each pass declares.

Adding an entry point: write ``def pass_x(hw) -> list[Finding]``, declare
the rules it exercises, and append a :class:`Pass` row to :data:`PASSES`.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import tempfile
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts, kernel_check
from repro.analysis.jaxpr_lint import RULES, Finding, LintTarget

__all__ = ["Pass", "PASSES", "run_passes", "selftest"]

_D, _R, _G, _N = 8, 2, 3, 4


def _tiny_problem():
    from repro.core.minimax import MinimaxProblem, project_simplex
    rng = np.random.RandomState(0)
    a = np.stack([rng.randn(_D, _D) for _ in range(_G)])
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)

    def loss_fn(x, y, batch):
        ag = a + batch
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return jnp.dot(y, lg) - jnp.sum((y - 1.0 / _G) ** 2)

    return MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          manifold_map={"w": "stiefel"})


def _tiny_init():
    from repro.core import manifolds as M
    from repro.core.gda import broadcast_to_nodes
    x0 = broadcast_to_nodes(
        {"w": M.random_stiefel(jax.random.PRNGKey(5), _D, _R)}, _N)
    y0 = jnp.full((_N, _G), 1.0 / _G)
    batch = 0.1 * jax.random.normal(jax.random.PRNGKey(6), (_N, _G, _D, _D))
    return x0, y0, batch


def _optimizers(telemetry=None):
    from repro.core import OPTIMIZERS
    from repro.core.gossip import GossipSpec
    prob = _tiny_problem()
    spec = GossipSpec(topology="ring", n_nodes=_N)
    return {name: cls(prob, spec, telemetry=telemetry)
            for name, cls in OPTIMIZERS.items()}


def pass_optimizer_state(hw) -> list[Finding]:
    """weak-type-leak over every optimizer's init state (PR-6 bug class)."""
    x0, y0, batch = _tiny_init()
    findings = []
    for name, opt in _optimizers().items():
        state = opt.init(x0, y0, batch)
        target = LintTarget(name=f"{name}.init", state=state)
        findings.extend(RULES["weak-type-leak"](target))
    return findings


def pass_optimizer_donation(hw) -> list[Finding]:
    """donation-miss over every optimizer's step (donate_argnums=(0,))."""
    x0, y0, batch = _tiny_init()
    findings = []
    for name, opt in _optimizers().items():
        state = opt.init(x0, y0, batch)
        steps = [("step", opt.step)]
        if hasattr(opt, "anchor_step"):
            steps.append(("anchor_step", opt.anchor_step))
        for label, fn in steps:
            args = (state, batch)
            jaxpr = jax.make_jaxpr(fn)(*args)
            target = LintTarget(name=f"{name}.{label}", jaxpr=jaxpr,
                                args=args, donate_argnums=(0,))
            findings.extend(RULES["donation-miss"](target))
    return findings


def pass_quiet_path(hw) -> list[Finding]:
    """effect-in-quiet-path over the quiet executable of make_obs_step for
    every optimizer, with live telemetry attached (flush cadence 50)."""
    from repro.obs import Telemetry
    x0, y0, batch = _tiny_init()
    findings = []
    with tempfile.TemporaryDirectory() as td:
        tel = Telemetry(run="analysis", out_dir=td, flush_every=50)
        for name, opt in _optimizers(telemetry=tel).items():
            state = opt.init(x0, y0, batch)

            def quiet(state, batch, _opt=opt):
                with tel.flush_mode("never"):
                    return _opt.step(state, batch)

            jaxpr = jax.make_jaxpr(quiet)(state, batch)
            target = LintTarget(name=f"{name}.quiet_step", jaxpr=jaxpr)
            findings.extend(RULES["effect-in-quiet-path"](target))

            # sanity: the flushing executable MUST carry the io effect —
            # otherwise telemetry is silently dead and this pass is vacuous
            def flushing(state, batch, _opt=opt):
                with tel.flush_mode("always"):
                    return _opt.step(state, batch)

            if not jax.make_jaxpr(flushing)(state, batch).effects:
                findings.append(Finding(
                    "effect-in-quiet-path", f"{name}.flush_step",
                    "flushing executable has no effects — telemetry flush "
                    "is not wired into this optimizer"))
    return findings


def pass_comm_schedule(hw) -> list[Finding]:
    """comm-schedule over the shard_map mix: fused k=3 is one megakernel
    launch behind one ppermute pair; unfused is one launch + pair per hop;
    the ring path never lowers a dense contraction.  Needs >= 8 devices
    (CI sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    if len(jax.devices()) < 8:
        return []    # single-device run: covered by the equiv-8dev CI job
    from jax.sharding import Mesh
    from repro.comms.backend import ShardMapBackend
    from repro.core.gossip import GossipSpec
    mesh = Mesh(np.asarray(jax.devices())[:8].reshape(8), ("node",))
    # 32 nodes over 8 devices: b = 4 rows/device, so the fused halo panel
    # and the unfused interior combine are both real (same geometry the
    # megakernel tests assert on)
    spec = GossipSpec(topology="ring", n_nodes=32, self_weight=1.0 / 3.0)
    tree = jax.random.normal(jax.random.PRNGKey(0), (32, 427), jnp.float32)
    findings = []
    with _forced_impl("pallas_interpret"):
        for fuse, expect_calls, expect_pp in (("on", 1, 2), ("off", 3, 6)):
            be = ShardMapBackend(mesh, axis="node", fuse=fuse)
            jaxpr = jax.make_jaxpr(lambda t, be=be: be.mix(spec, t, 3))(tree)
            target = LintTarget(name=f"shard_map.mix[fuse={fuse}]",
                                jaxpr=jaxpr)
            findings.extend(RULES["comm-schedule"](
                target, expect_ppermute=expect_pp,
                expect_kernel_calls=expect_calls,
                kernel_names=("multi_hop_mix", "ring_mix"),
                forbid_primitives=("dot_general",)))
    return findings


@contextlib.contextmanager
def _forced_impl(impl: str):
    prev = os.environ.get("REPRO_KERNEL_IMPL")
    os.environ["REPRO_KERNEL_IMPL"] = impl
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_KERNEL_IMPL", None)
        else:
            os.environ["REPRO_KERNEL_IMPL"] = prev


def pass_serve_state(hw) -> list[Finding]:
    """weak-type-leak over the serve layer's carried device state: the KV
    pools and the ReplicaGroup's stacked parameter tree."""
    from repro import configs
    from repro.models import transformer as T
    from repro.serve.kv_cache import PagedKVSpec, init_pools
    from repro.serve.replica import ReplicaGroup
    cfg = configs.get_config("smollm-135m", smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    findings = []
    pools = init_pools(cfg, PagedKVSpec(), params["embed"].dtype)
    findings.extend(RULES["weak-type-leak"](
        LintTarget(name="serve.pools", state=pools)))
    rg = ReplicaGroup(params, n_replicas=2)
    findings.extend(RULES["weak-type-leak"](
        LintTarget(name="serve.replica_group", state=rg.params)))
    return findings


def pass_kernels(hw) -> list[Finding]:
    return kernel_check.run(hw)


def pass_contracts(hw) -> list[Finding]:
    return contracts.run()


@dataclasses.dataclass(frozen=True)
class Pass:
    name: str
    rules: tuple        # rule names this pass exercises (for --rules filter)
    fn: Callable


PASSES = (
    Pass("optimizer-state", ("weak-type-leak",), pass_optimizer_state),
    Pass("optimizer-donation", ("donation-miss",), pass_optimizer_donation),
    Pass("quiet-path", ("effect-in-quiet-path",), pass_quiet_path),
    Pass("comm-schedule", ("comm-schedule",), pass_comm_schedule),
    Pass("serve-state", ("weak-type-leak",), pass_serve_state),
    Pass("kernels", ("vmem-budget", "tiling", "oracle-coverage"),
         pass_kernels),
    Pass("contracts", ("doubly-stochastic", "manifold-feasibility"),
         pass_contracts),
)


def run_passes(rules: set[str] | None = None, hw=None,
               ) -> dict[str, list[Finding]]:
    """Run every pass whose declared rules intersect ``rules`` (all when
    None); returns {pass name: findings}."""
    out: dict[str, list[Finding]] = {}
    for p in PASSES:
        if rules is not None and not rules.intersection(p.rules):
            continue
        out[p.name] = p.fn(hw)
    return out


# --------------------------------------------------------------------------
# selftest: seeded known-bad fixtures each pass must catch
# --------------------------------------------------------------------------

def selftest() -> list[str]:
    """Prove the analyzers fire: a weak_type init leaf, an over-VMEM block
    config, and a sub-stochastic W_t must each produce findings.  Returns
    a list of failures (empty == every pass caught its fixture)."""
    failures = []

    # 1. weak_type init leaf — the exact PR-6 shape (jnp.full y0)
    bad_state = {"y": jnp.full((_N, _G), 1.0 / _G),
                 "x": jnp.zeros((_N, _D, _R))}
    found = RULES["weak-type-leak"](LintTarget(name="selftest", state=bad_state))
    if not any(".y" in f.where or "'y'" in f.where for f in found):
        failures.append("weak-type-leak missed a jnp.full weak_type leaf")

    # 2. over-VMEM launch config: a 2M-lane feature block on the megakernel
    found = kernel_check.vmem_findings(
        "multi_hop_mix", {"block_f": 1 << 21},
        dims={"rows": 64, "out_rows": 32})
    if not found:
        failures.append("vmem-budget missed a ~1.5 GiB block config")

    # 3. sub-stochastic W_t: a channel whose faulty round leaks row mass
    class _LeakyChannel:
        def w_t(self, rnd, key):
            from repro.core.gossip import ring_matrix
            w = jnp.asarray(ring_matrix(_N), jnp.float32)
            return w * 0.9    # dropped weight NOT folded into the diagonal

    found = contracts.doubly_stochastic_findings(
        _LeakyChannel(), rounds=3, where="selftest")
    if not found:
        failures.append("doubly-stochastic missed a 0.9-scaled W_t")

    # 4. donation-miss: two state leaves donated into one output buffer
    def collapse(state):
        return state["a"] + state["b"]

    args = ({"a": jnp.zeros((4, 4)), "b": jnp.zeros((4, 4))},)
    jaxpr = jax.make_jaxpr(collapse)(*args)
    found = RULES["donation-miss"](LintTarget(
        name="selftest", jaxpr=jaxpr, args=args, donate_argnums=(0,)))
    if not found:
        failures.append("donation-miss missed a collapsed donation")

    # 5. effect-in-quiet-path: a program with a live io_callback
    from jax.experimental import io_callback

    def noisy(x):
        io_callback(lambda a: None, None, x)
        return x + 1

    jaxpr = jax.make_jaxpr(noisy)(jnp.zeros((2,)))
    found = RULES["effect-in-quiet-path"](LintTarget(name="selftest",
                                                     jaxpr=jaxpr))
    if not found:
        failures.append("effect-in-quiet-path missed an io_callback")

    return failures
