"""repro.analysis — static analysis over the repo's jitted surfaces.

Three passes, one engine:

* :mod:`~repro.analysis.jaxpr_lint` — pluggable rules over ClosedJaxprs
  (``weak-type-leak``, ``effect-in-quiet-path``, ``donation-miss``,
  ``comm-schedule``) plus the runtime :class:`RecompileSentinel`;
* :mod:`~repro.analysis.kernel_check` — VMEM footprints vs the
  :class:`~repro.launch.roofline.HardwareModel` budget, tiling contracts,
  and the oracle-coverage gate over ``kernels/ops.py``;
* :mod:`~repro.analysis.contracts` — doubly-stochastic W_t and manifold
  feasibility validators.

CLI: ``python -m repro.analysis [--rules ...] [--hw tpu_v5e]`` exits
nonzero on violations; ``--selftest`` proves each pass fires on seeded
known-bad fixtures.  Tests consume the same engine via
:func:`assert_jaxpr_rule`.
"""
from repro.analysis.jaxpr_lint import (Finding, LintTarget,  # noqa: F401
                                       RecompileError, RecompileSentinel,
                                       RULES, assert_jaxpr_rule,
                                       count_primitive, iter_eqns,
                                       kernel_call_sites, lint)
from repro.analysis import contracts, kernel_check  # noqa: F401
