"""Pallas kernel checker: VMEM budgets, tiling contracts, oracle coverage.

Three static passes over the kernel layer, no kernel execution required:

- **VMEM footprint** — every launch config's resident bytes per grid step
  (input + output blocks x dtypes, double-buffered for the pipelined DMA,
  plus scratch) estimated against the :class:`~repro.launch.roofline.
  HardwareModel` ``vmem_bytes`` budget (~16 MiB/core on every current TPU).
  The estimators mirror the real ``BlockSpec``s in ``kernels/*.py``.
- **Tiling contracts** — the ``ops.py`` dispatch wrappers promise
  "arbitrary leaf sizes in, padded panels out"; this pass re-derives each
  wrapper's pad-and-pick-block arithmetic over ragged (prime) shapes and
  fails if any shape escapes the kernel's ``dim % block == 0`` assert or
  loses tail elements.
- **Oracle coverage** — introspects ``kernels/ops.py`` (AST, not import
  side effects) and fails if any dispatched kernel lacks a ``ref.py``
  oracle call, an ``Estimates`` recorder registered in
  ``obs.estimates.KERNELS``, or — when it consults the autotuner — a
  ``tune.py`` registration (DEFAULTS + SPACES, which the search gates at
  ``ACCURACY_RTOL`` against the default config's output).
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.jaxpr_lint import Finding
from repro.launch import roofline

__all__ = ["vmem_footprint", "vmem_findings", "check_vmem",
           "check_tiling", "check_oracle_coverage", "run"]

_F32 = 4
_I32 = 4
_I8 = 1


# --------------------------------------------------------------------------
# VMEM footprint estimators (mirror the BlockSpecs in kernels/*.py)
# --------------------------------------------------------------------------

def _ring_mix_fp(dims: dict, cfg: dict) -> int:
    br = cfg.get("block_rows", 256)
    # 3 input panels + 1 output, (block_rows, 128) fp32
    return 4 * br * 128 * _F32


def _quant_mix_fp(dims: dict, cfg: dict) -> int:
    bc = cfg.get("block_cols", 2048)
    q = 3 * 32 * bc * _I8          # int8 payloads, (32, block_cols)
    s = 3 * 32 * 1 * _F32          # per-row scales
    out = 32 * bc * dims.get("out_itemsize", _F32)
    return q + s + out


def _multi_hop_fp(dims: dict, cfg: dict) -> int:
    bf = cfg.get("block_f", 1024)
    rows, out_rows = dims["rows"], dims["out_rows"]
    return (rows + out_rows) * bf * _F32


def _multi_hop_quant_fp(dims: dict, cfg: dict) -> int:
    bf = cfg.get("block_f", 1024)
    rows = dims["rows"]
    blocks = rows * bf * _I8 + rows * 1 * _F32 + rows * bf * _F32
    scratch = 2 * rows * 128 * _F32      # |z| max + finalized scales
    return blocks + _scratch_once(scratch)


def _fused_retract_fp(dims: dict, cfg: dict) -> int:
    bd, r = cfg.get("block_d", 256), dims["r"]
    blocks = 3 * bd * r * _F32           # x, g blocks + output block
    scratch = 4 * r * r * _F32           # B, C, M1, M2 accumulators
    return blocks + _scratch_once(scratch)


def _stiefel_project_fp(dims: dict, cfg: dict) -> int:
    bd, r = cfg.get("block_d", 128), dims["r"]
    blocks = 3 * bd * r * _F32 + r * r * _F32
    scratch = r * r * _F32
    return blocks + _scratch_once(scratch)


def _flash_attention_fp(dims: dict, cfg: dict) -> int:
    bq, bk = cfg.get("block_q", 128), cfg.get("block_kv", 128)
    hd, hdv = dims["hd"], dims.get("hdv", dims["hd"])
    blocks = (bq * _I32 + bk * _I32              # position blocks
              + bq * hd * _F32 + bk * hd * _F32 + bk * hdv * _F32
              + bq * hdv * _F32)                 # q, k, v, out
    scratch = (bq * hdv + 2 * bq) * _F32         # acc + m + l
    return blocks + _scratch_once(scratch)


def _paged_decode_fp(dims: dict, cfg: dict) -> int:
    ppb = cfg.get("pages_per_block", 1)
    ps, group = dims["ps"], dims["group"]
    hd, hdv = dims["hd"], dims.get("hdv", dims["hd"])
    blocks = (group * hd * _F32
              + ppb * ps * hd * _F32 + ppb * ps * hdv * _F32
              + group * hdv * _F32)
    scratch = (group * hdv + 2 * group) * _F32
    return blocks + _scratch_once(scratch)


def _scratch_once(nbytes: int) -> int:
    # scratch_shapes are allocated once, not double-buffered; halve here and
    # let vmem_footprint apply the uniform x2 to everything
    return nbytes // 2


_FOOTPRINTS = {
    "ring_mix": _ring_mix_fp,
    "quant_mix": _quant_mix_fp,
    "multi_hop_mix": _multi_hop_fp,
    "multi_hop_mix_quant": _multi_hop_quant_fp,
    "fused_retract": _fused_retract_fp,
    "stiefel_project": _stiefel_project_fp,
    "flash_attention": _flash_attention_fp,
    "paged_decode": _paged_decode_fp,
}

#: representative dims per kernel for config sweeps: the ROADMAP target
#: shapes (d=4096 r=128 retract; tiny_64k 8-node mix panel; 128-wide heads)
REPRESENTATIVE = {
    "ring_mix": {},
    "quant_mix": {"out_itemsize": 4},
    "multi_hop_mix": {"rows": 136, "out_rows": 128},
    "multi_hop_mix_quant": {"rows": 160},
    "fused_retract": {"r": 128},
    "stiefel_project": {"r": 128},
    "flash_attention": {"hd": 128, "hdv": 128},
    "paged_decode": {"ps": 64, "group": 8, "hd": 128, "hdv": 128},
}


def vmem_footprint(kernel: str, dims: dict, cfg: dict) -> int:
    """Estimated resident VMEM bytes per grid step, double-buffered."""
    if kernel not in _FOOTPRINTS:
        raise KeyError(f"no footprint model for kernel {kernel!r}; add one "
                       "to _FOOTPRINTS mirroring its BlockSpecs")
    return 2 * _FOOTPRINTS[kernel](dims, cfg)


def vmem_findings(kernel: str, cfg: dict, *, dims: dict | None = None,
                  hw: roofline.HardwareModel | None = None) -> list[Finding]:
    """Check one launch config against the hardware VMEM budget."""
    hw = hw or roofline.get_hardware()
    dims = {**REPRESENTATIVE.get(kernel, {}), **(dims or {})}
    fp = vmem_footprint(kernel, dims, cfg)
    if fp > hw.vmem_bytes:
        return [Finding(
            "vmem-budget", f"{kernel} {cfg}",
            f"estimated footprint {fp / 2**20:.1f} MiB exceeds {hw.name} "
            f"VMEM budget {hw.vmem_bytes / 2**20:.0f} MiB")]
    return []


def check_vmem(hw: roofline.HardwareModel | None = None) -> list[Finding]:
    """Sweep every registered launch config (tune DEFAULTS + SPACES)."""
    from repro.kernels import tune
    hw = hw or roofline.get_hardware()
    findings = []
    for kernel in _FOOTPRINTS:
        configs = [tune.DEFAULTS.get(kernel, {})] + tune.SPACES.get(kernel, [])
        for cfg in configs:
            findings.extend(vmem_findings(kernel, cfg, hw=hw))
    return findings


# --------------------------------------------------------------------------
# tiling contracts: pad-and-pick-block arithmetic over ragged shapes
# --------------------------------------------------------------------------

#: ragged sizes the dispatch wrappers must cover without tripping a kernel's
#: divisibility assert: primes, one-off-tile, sub-tile, and aligned sizes
RAGGED_SIZES = (1, 7, 97, 127, 129, 1009, 4093, 8191, 8192, 65536, 99991)


def _pick(padded: int, cands: list[int]) -> int:
    for c in cands:
        if padded % c == 0:
            return c
    return padded


def check_tiling() -> list[Finding]:
    findings = []

    # ring_mix: flatten to (rows, 128), pad rows to 8, block from candidates
    for n in RAGGED_SIZES:
        rows = -(-n // 128)
        rows_p = rows + (-rows) % 8
        block = _pick(rows_p, [256, 128, 64, 32, 16, 8])
        if rows_p % block or rows_p * 128 < n:
            findings.append(Finding(
                "tiling", f"ring_mix n={n}",
                f"padded panel ({rows_p},128) not covered by "
                f"block_rows={block}"))

    # quant_mix: (rows, cols) int8, rows->32 sublanes, cols->128 lanes
    for rows in (1, 31, 32, 97):
        for cols in RAGGED_SIZES:
            rows_p = rows + (-rows) % 32
            cols_p = cols + (-cols) % 128
            block_c = _pick(cols_p, [2048, 1024, 512, 256, 128])
            if rows_p % 32 or cols_p % block_c or cols_p < cols:
                findings.append(Finding(
                    "tiling", f"quant_mix rows={rows} cols={cols}",
                    f"padded ({rows_p},{cols_p}) not tiled by "
                    f"(32,{block_c})"))

    # multi_hop_mix(+quant): lane tail -> 128, row tail -> 8 (fp32) / 32
    # (int8); block_f fallback chain must always divide the padded width
    for f in RAGGED_SIZES:
        f_p = f + (-f) % 128
        block = _pick(f_p, [1024, 4096, 2048, 512, 256, 128])
        if f_p % block or f_p < f:
            findings.append(Finding(
                "tiling", f"multi_hop_mix f={f}",
                f"padded width {f_p} not divided by block_f={block} "
                "(the 128 fallback should always divide a 128-multiple)"))

    # fused_retract / stiefel_project: d,r pad to 128; block_d falls back
    # to 128 whenever the tuned/explicit block does not divide
    for d in (3, 100, 127, 129, 4096):
        d_p = d + (-d) % 128
        for block_d in (256, 128, 512):
            eff = block_d if d_p % block_d == 0 else 128
            if d_p % eff:
                findings.append(Finding(
                    "tiling", f"fused_retract d={d} block_d={block_d}",
                    f"effective block {eff} does not divide padded d={d_p}"))

    # flash_attention: seq tails pad to min(block, seq); the kernel then
    # runs with block=min(block, padded) which must divide
    for s in (1, 5, 127, 128, 1000):
        for block in (64, 128, 256):
            eff = min(block, max(s, 1))
            s_p = s + (-s) % eff
            if s_p % min(block, s_p):
                findings.append(Finding(
                    "tiling", f"flash_attention seq={s} block={block}",
                    f"padded seq {s_p} not divided by {min(block, s_p)}"))

    # paged_decode: block table padded with -1 columns to pages_per_block
    for m_pages in (1, 3, 7, 16):
        for ppb in (1, 2, 4, 8):
            m_p = m_pages + (-m_pages) % max(ppb, 1)
            if m_p % max(ppb, 1) or m_p < m_pages:
                findings.append(Finding(
                    "tiling", f"paged_decode m_pages={m_pages} ppb={ppb}",
                    f"padded table width {m_p} not divided by {ppb}"))

    return findings


# --------------------------------------------------------------------------
# oracle-coverage gate: AST introspection of kernels/ops.py
# --------------------------------------------------------------------------

def _ops_path() -> Path:
    from repro import kernels
    return Path(kernels.__file__).parent / "ops.py"


def _scan_ops(path: Path | None = None) -> dict[str, dict]:
    """Per dispatched kernel (one ``_est.record("<name>", ...)`` call):
    whether its wrapper calls a ``ref.*`` oracle and which tune keys it
    consults (directly or through ``_pick_block_f``)."""
    tree = ast.parse((path or _ops_path()).read_text())
    out: dict[str, dict] = {}
    for node in tree.body:
        if not isinstance(node, ast.FunctionDef):
            continue
        recorded, tuned, has_ref = [], [], False
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            f = call.func
            if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
                head, attr = f.value.id, f.attr
                lit = (call.args[0].value
                       if call.args and isinstance(call.args[0], ast.Constant)
                       and isinstance(call.args[0].value, str) else None)
                if head == "_est" and attr == "record" and lit:
                    recorded.append(lit)
                elif head == "_tune" and attr == "lookup" and lit:
                    tuned.append(lit)
                elif head == "ref":
                    has_ref = True
            elif isinstance(f, ast.Name) and f.id == "_pick_block_f":
                if call.args and isinstance(call.args[0], ast.Constant):
                    tuned.append(call.args[0].value)
        for name in recorded:
            out[name] = {"fn": node.name, "has_ref": has_ref,
                         "tune_keys": tuned}
    return out


def check_oracle_coverage(path: Path | None = None) -> list[Finding]:
    """Every dispatched kernel needs: a ref.py oracle, an Estimates
    recorder registered in ``obs.estimates.KERNELS``, and (when it consults
    the autotuner) ``tune.py`` DEFAULTS + SPACES entries so the accuracy
    gate (``ACCURACY_RTOL`` vs the default config) applies to it."""
    from repro.kernels import tune
    from repro.obs import estimates
    findings = []
    kernels = _scan_ops(path)
    if not kernels:
        findings.append(Finding("oracle-coverage", "ops.py",
                                "no dispatched kernels found — scan broken?"))
    for name, info in sorted(kernels.items()):
        where = f"ops.{info['fn']}"
        if not info["has_ref"]:
            findings.append(Finding(
                "oracle-coverage", where,
                f"kernel {name!r} dispatches with no ref.py oracle call — "
                "the interpret/CPU path and the accuracy gate have nothing "
                "to check against"))
        if name not in estimates.KERNELS:
            findings.append(Finding(
                "oracle-coverage", where,
                f"kernel {name!r} records estimates under a name missing "
                "from obs.estimates.KERNELS"))
        for key in info["tune_keys"]:
            if key not in tune.DEFAULTS:
                findings.append(Finding(
                    "oracle-coverage", where,
                    f"tunable kernel {key!r} has no tune.DEFAULTS entry"))
            if key not in tune.SPACES:
                findings.append(Finding(
                    "oracle-coverage", where,
                    f"tunable kernel {key!r} has no tune.SPACES entry — "
                    "the accuracy-gated search cannot cover it"))
    # stale registrations: every tune/estimates key must be dispatched
    for key in tune.DEFAULTS:
        if key not in kernels:
            findings.append(Finding(
                "oracle-coverage", f"tune.DEFAULTS[{key!r}]",
                "registered tune key is never dispatched from ops.py"))
    for key in estimates.KERNELS:
        if key not in kernels:
            findings.append(Finding(
                "oracle-coverage", f"estimates.KERNELS[{key!r}]",
                "registered estimator is never recorded from ops.py"))
    return findings


def run(hw: roofline.HardwareModel | None = None) -> list[Finding]:
    """All kernel-checker passes."""
    return check_vmem(hw) + check_tiling() + check_oracle_coverage()
