"""Numerical contract validators: doubly-stochastic W_t, manifold feasibility.

The paper's Theorem 1 rates (DRGDA O(eps^-2), DRSGDA O(eps^-4)) assume the
effective mixing matrix of every gossip round is symmetric doubly
stochastic — including rounds where the :class:`~repro.comms.channel.
ChannelModel` drops links or deactivates edges under a round-robin/matching
schedule.  ``comms.channel`` maintains this by folding dropped off-diagonal
weight back into the diagonal; these validators re-check the invariant
numerically over seeded draws rather than trusting the construction.

The manifold contracts do the same for the geometry layer: every registered
manifold's retraction must land on the manifold (``check()`` small) from a
random feasible point and tangent direction, for every retraction it
advertises.
"""
from __future__ import annotations

from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.jaxpr_lint import Finding

__all__ = ["matrix_findings", "doubly_stochastic_findings",
           "elastic_sweep_findings", "manifold_findings", "run"]


def matrix_findings(w: Any, *, where: str = "W", tol: float = 1e-5,
                    require_symmetric: bool = True) -> list[Finding]:
    """Check one mixing matrix: row/col sums == 1, entries >= 0, symmetry."""
    findings = []
    w = np.asarray(w)
    if w.ndim != 2 or w.shape[0] != w.shape[1]:
        return [Finding("doubly-stochastic", where,
                        f"not a square matrix: shape {w.shape}")]
    rows = np.abs(w.sum(axis=1) - 1.0)
    cols = np.abs(w.sum(axis=0) - 1.0)
    if rows.max() > tol:
        findings.append(Finding(
            "doubly-stochastic", where,
            f"row sums off by up to {rows.max():.2e} (tol {tol:.0e}); "
            "dropped link weight is not being folded back into the diagonal"))
    if cols.max() > tol:
        findings.append(Finding(
            "doubly-stochastic", where,
            f"column sums off by up to {cols.max():.2e} (tol {tol:.0e})"))
    if w.min() < -tol:
        findings.append(Finding(
            "doubly-stochastic", where,
            f"negative entry {w.min():.2e}: self-weight underflow "
            "(off-diagonal mass exceeds 1)"))
    if require_symmetric and np.abs(w - w.T).max() > tol:
        findings.append(Finding(
            "doubly-stochastic", where,
            f"asymmetric by {np.abs(w - w.T).max():.2e}; Theorem 1 needs "
            "symmetric W_t"))
    return findings


def doubly_stochastic_findings(channel: Any, *, rounds: int = 100,
                               seed: int = 0, tol: float = 1e-5,
                               where: str = "channel",
                               max_report: int = 5) -> list[Finding]:
    """Every effective W_t a channel draws over ``rounds`` seeded gossip
    rounds must stay symmetric doubly stochastic."""
    findings = []
    key = jax.random.PRNGKey(seed)
    for rnd in range(rounds):
        w_t = channel.w_t(rnd, jax.random.fold_in(key, rnd))
        findings.extend(matrix_findings(
            w_t, where=f"{where} round {rnd}", tol=tol))
        if len(findings) >= max_report:
            findings.append(Finding(
                "doubly-stochastic", where,
                f"stopping after {max_report} findings ({rounds - rnd - 1} "
                "rounds unchecked)"))
            break
    return findings


def channel_sweep_findings(*, n: int = 8, rounds: int = 20, seed: int = 0,
                           tol: float = 1e-5) -> list[Finding]:
    """Sweep topology x fault schedule: every combination the comms layer
    supports must keep effective W_t doubly stochastic."""
    from repro.comms.channel import ChannelModel
    from repro.core import gossip
    findings = []
    for topology in ("ring", "full", "torus", "star"):
        w = gossip.mixing_matrix(topology, n)
        findings.extend(matrix_findings(w, where=f"{topology}(n={n})",
                                        tol=tol))
        for schedule in ("static", "round_robin", "matching"):
            for drop, straggle in ((0.0, 0.0), (0.3, 0.0), (0.0, 0.3),
                                   (0.25, 0.25)):
                ch = ChannelModel(w, schedule=schedule, drop_rate=drop,
                                  straggler_rate=straggle, topology=topology)
                findings.extend(doubly_stochastic_findings(
                    ch, rounds=rounds, seed=seed, tol=tol,
                    where=f"{topology}/{schedule}/drop={drop}/"
                          f"strag={straggle}"))
    return findings


def elastic_sweep_findings(*, n: int = 8, rounds: int = 100, seed: int = 0,
                           tol: float = 1e-5,
                           max_report: int = 5) -> list[Finding]:
    """Elastic execution mode: every *realized* W_t — under scripted
    leave/rejoin, seeded-random churn, stragglers, stale-hop tolerance —
    must stay symmetric doubly stochastic, and every departed node's row
    must be exactly the identity row (it neither sends nor receives).

    Unlike :func:`doubly_stochastic_findings` this threads the real
    ``Membership`` state through ``ElasticEngine.mix`` round by round, so
    the matrices checked are the ones a training run would apply.
    """
    from repro.comms.elastic import ChurnSchedule, ElasticEngine, ElasticSpec
    from repro.core.gossip import GossipSpec
    schedules = {
        "static": ChurnSchedule(),
        "scripted": ChurnSchedule(kind="scripted", events=(
            (3, "leave", 1), (7, "leave", 4), (12, "join", 1),
            (20, "join", 4))),
        "random": ChurnSchedule(kind="random", leave_rate=0.2,
                                join_rate=0.5),
    }
    findings = []
    for sched_name, churn in schedules.items():
        for tau, drop, strag in ((0, 0.0, 0.3), (2, 0.2, 0.3)):
            spec = ElasticSpec(churn=churn, tau=tau, drop_rate=drop,
                               straggler_rate=strag, seed=seed)
            gossip = GossipSpec(topology="ring", n_nodes=n, k_steps=1,
                                elastic=spec)
            engine = ElasticEngine(gossip)
            x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 4))
            state = engine.init_state({"x": x})
            view_fn = jax.jit(lambda st, r: (
                engine.round_view(st, "x", r).wt,
                engine.round_view(st, "x", r).active))
            step_fn = jax.jit(
                lambda st, t, r: engine.mix(st, "x", t, steps=1, rnd=r)[1])
            where = (f"elastic/{sched_name}/tau={tau}/drop={drop}/"
                     f"strag={strag}")
            for rnd in range(rounds):
                wt, active = view_fn(state, rnd)
                wt, active = np.asarray(wt), np.asarray(active)
                findings.extend(matrix_findings(
                    wt, where=f"{where} round {rnd}", tol=tol))
                dead = np.where(active == 0)[0]
                eye = np.eye(n, dtype=wt.dtype)
                for i in dead:
                    if np.abs(wt[i] - eye[i]).max() > tol:
                        findings.append(Finding(
                            "doubly-stochastic", f"{where} round {rnd}",
                            f"departed node {i}'s row is not the identity "
                            "row: it would still send/receive"))
                if len(findings) >= max_report:
                    findings.append(Finding(
                        "doubly-stochastic", where,
                        f"stopping after {max_report} findings"))
                    return findings
                state = step_fn(state, x, rnd)
    return findings


def manifold_findings(*, seed: int = 0, d: int = 12, r: int = 4,
                      step: float = 0.1, tol: float = 1e-4,
                      names: Iterable[str] | None = None) -> list[Finding]:
    """Retraction output must pass ``check()`` for every registered manifold
    and every retraction it advertises, from seeded feasible points."""
    from repro import geometry
    findings = []
    key = jax.random.PRNGKey(seed)
    for name in sorted(names or geometry.REGISTRY):
        m = geometry.REGISTRY[name]
        k1, k2 = jax.random.split(jax.random.fold_in(key, hash(name) % 2**31))
        x = m.rand(k1, d, r)
        feas = float(m.check(x))
        if not np.isfinite(feas) or feas > tol:
            findings.append(Finding(
                "manifold-feasibility", f"{name}.rand",
                f"random point infeasible: check()={feas:.2e} (tol {tol:.0e})"))
            continue
        g = jax.random.normal(k2, x.shape, x.dtype)
        u = m.tangent_project(x, g)
        for kind in m.retractions:
            y = m.retract(x, step * u, kind)
            resid = float(m.check(y))
            if not np.isfinite(resid) or resid > tol:
                findings.append(Finding(
                    "manifold-feasibility", f"{name}.retract[{kind}]",
                    f"retraction leaves the manifold: check()={resid:.2e} "
                    f"(tol {tol:.0e})"))
            if not bool(jnp.all(jnp.isfinite(y))):
                findings.append(Finding(
                    "manifold-feasibility", f"{name}.retract[{kind}]",
                    "retraction produced non-finite entries"))
    return findings


def run(*, rounds: int = 20) -> list[Finding]:
    """All numerical contract validators."""
    return (channel_sweep_findings(rounds=rounds)
            + elastic_sweep_findings()
            + manifold_findings())
