"""Jaxpr lint engine: pluggable rules over traced step/mix/serve functions.

The repo's correctness rests on invariants that live *in the jaxpr*, not in
output values: carried optimizer state must keep stable avals across steps
(a ``weak_type`` leaf silently retraces on step 2 — the PR-6 bug class),
the quiet executable of ``make_obs_step`` must stay effect-free (any program
containing an ``io_callback`` loses XLA's fast dispatch path), declared
donations must actually alias an output buffer, and the gossip ring path
must lower to ``ppermute`` + Pallas combine with no dense contraction.

Each rule is a function ``rule(target: LintTarget, **params) -> [Finding]``
registered in :data:`RULES`.  Tests consume the same engine through
:func:`assert_jaxpr_rule`; the CLI (``python -m repro.analysis``) runs the
rules over the registered entry points in ``entrypoints.py``.

Adding a rule: write ``def rule_my_check(target, **params)`` returning a
list of :class:`Finding`, add it to :data:`RULES`, and (if it should run on
the repo's standard targets) register a target in ``entrypoints.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp

__all__ = [
    "Finding", "LintTarget", "RULES", "lint", "assert_jaxpr_rule",
    "iter_eqns", "count_primitive", "kernel_call_sites",
    "RecompileSentinel", "RecompileError",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, printable as ``[rule] where: message``."""

    rule: str
    where: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.where}: {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class LintTarget:
    """One lintable entity.

    Rules read only the fields they need: jaxpr rules read ``jaxpr`` (a
    ``ClosedJaxpr``), the weak-type rule reads ``state`` (a pytree of carried
    values), and the donation rule additionally reads ``args`` (the example
    arguments the jaxpr was traced with, to map flattened invars back to
    argnums/paths) and ``donate_argnums``.
    """

    name: str
    jaxpr: Any = None
    state: Any = None
    args: Any = None
    donate_argnums: tuple = ()


# --------------------------------------------------------------------------
# jaxpr walking
# --------------------------------------------------------------------------

def _jaxprs_of(val: Any) -> Iterator[Any]:
    # Duck-typed so this survives jax.core churn: a Jaxpr has .eqns, a
    # ClosedJaxpr wraps one as .jaxpr; call-primitive params hold either,
    # and cond holds a tuple of branches.
    if hasattr(val, "eqns"):
        yield val
    elif hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
        yield val.jaxpr
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _jaxprs_of(v)


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """Depth-first over all eqns, descending into pjit/scan/cond/shard_map
    sub-jaxprs held in eqn params."""
    if hasattr(jaxpr, "jaxpr"):          # accept a ClosedJaxpr too
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            for sub in _jaxprs_of(val):
                yield from iter_eqns(sub)


def count_primitive(closed_jaxpr: Any, name: str) -> int:
    """Structural count of a primitive across the whole (nested) jaxpr."""
    return sum(1 for eqn in iter_eqns(closed_jaxpr) if eqn.primitive.name == name)


def kernel_call_sites(closed_jaxpr: Any, kernel_names: Iterable[str]) -> int:
    """Count kernel-wrapper call sites by name in the printed jaxpr.

    The jaxpr printer emits one ``name=<kernel>`` per call site (identical
    sub-jaxpr *bodies* dedup, call sites do not), so a textual count is the
    reliable way to count launches of a jitted Pallas wrapper — the same
    convention the megakernel tests used before migrating onto this engine.
    """
    names = list(kernel_names)
    if not names:
        return 0
    pat = "name=(?:" + "|".join(re.escape(n) for n in names) + ")"
    return len(re.findall(pat, str(closed_jaxpr)))


# --------------------------------------------------------------------------
# rules
# --------------------------------------------------------------------------

_PROMOTED_DTYPES = ("float64", "complex128", "int64", "uint64")


def rule_weak_type_leak(target: LintTarget, *,
                        allowed_dtypes: Iterable[str] | None = None,
                        ) -> list[Finding]:
    """Carried state must be strongly typed and not silently promoted.

    A ``weak_type`` leaf in an optimizer/serve state changes aval once the
    first step computes a strong value for it, forcing a retrace on call 2
    with no error — only a mysterious mid-training stall.  ``allowed_dtypes``
    optionally restricts leaves to an explicit dtype whitelist.
    """
    findings = []
    allowed = set(allowed_dtypes) if allowed_dtypes is not None else None
    leaves, _ = jax.tree_util.tree_flatten_with_path(target.state)
    for path, leaf in leaves:
        if not hasattr(leaf, "dtype"):
            continue
        where = f"{target.name}{jax.tree_util.keystr(path)}"
        dtype = jnp.dtype(leaf.dtype).name
        if getattr(leaf, "weak_type", False):
            findings.append(Finding(
                "weak-type-leak", where,
                f"leaf is weak_type {dtype}; the first strongly-typed update "
                f"changes the carried aval and silently retraces the step "
                f"(wrap the constructor output in a strong astype)"))
        if dtype in _PROMOTED_DTYPES:
            findings.append(Finding(
                "weak-type-leak", where,
                f"leaf dtype promoted to {dtype}; 64-bit/complex state "
                f"doubles wire bytes and breaks the int8 gossip path"))
        if allowed is not None and dtype not in allowed:
            findings.append(Finding(
                "weak-type-leak", where,
                f"leaf dtype {dtype} not in allowed set {sorted(allowed)}"))
    return findings


_CALLBACK_PRIMS = frozenset(
    {"io_callback", "pure_callback", "debug_callback", "callback"})


def rule_effect_in_quiet_path(target: LintTarget) -> list[Finding]:
    """The quiet executable of a dual-executable step must be effect-free.

    Any XLA program *containing* an io_callback loses the fast dispatch
    path (~60% overhead for the naive ``lax.cond`` flush, measured in PR 6),
    so the quiet path must not merely skip the callback — it must not
    contain one at all.
    """
    findings = []
    cj = target.jaxpr
    effects = getattr(cj, "effects", None)
    if effects:
        findings.append(Finding(
            "effect-in-quiet-path", target.name,
            "quiet executable carries effects "
            f"{sorted(type(e).__name__ for e in effects)}; it will not use "
            "XLA's fast dispatch path"))
    for eqn in iter_eqns(cj):
        if eqn.primitive.name in _CALLBACK_PRIMS:
            findings.append(Finding(
                "effect-in-quiet-path", target.name,
                f"primitive `{eqn.primitive.name}` reachable from the quiet "
                "executable"))
    return findings


def rule_donation_miss(target: LintTarget) -> list[Finding]:
    """Every declared-donated input buffer must have a matching output aval.

    XLA only reuses a donated buffer for an output of identical
    (shape, dtype), and each output absorbs at most one donation — two
    state leaves sharing one buffer (e.g. ``u`` initialized as an alias of
    ``gx_prev``) silently drop one donation.  This is a static check on the
    traced avals: a donated invar with no remaining matching outvar is
    flagged.
    """
    cj, args = target.jaxpr, target.args
    if cj is None or args is None:
        raise ValueError("donation-miss needs target.jaxpr and target.args")
    flat: list[tuple[int, str]] = []
    for i, arg in enumerate(args):
        arg_leaves, _ = jax.tree_util.tree_flatten_with_path(arg)
        for path, _leaf in arg_leaves:
            flat.append((i, jax.tree_util.keystr(path)))
    invars = cj.jaxpr.invars
    if len(flat) != len(invars):
        raise ValueError(
            f"{target.name}: example args flatten to {len(flat)} leaves but "
            f"the jaxpr has {len(invars)} invars; trace with the same "
            "(non-static) arguments")
    pool: collections.Counter = collections.Counter()
    for v in cj.jaxpr.outvars:
        aval = getattr(v, "aval", None)
        if hasattr(aval, "shape"):
            pool[(tuple(aval.shape), jnp.dtype(aval.dtype).name)] += 1
    findings = []
    for (argnum, pathstr), var in zip(flat, invars):
        if argnum not in target.donate_argnums:
            continue
        key = (tuple(var.aval.shape), jnp.dtype(var.aval.dtype).name)
        if pool[key] > 0:
            pool[key] -= 1
        else:
            findings.append(Finding(
                "donation-miss", f"{target.name} arg{argnum}{pathstr}",
                f"donated buffer f{key[1]}{list(key[0])} has no matching "
                "output aval left to alias; XLA silently ignores the "
                "donation and allocates a copy"))
    return findings


def rule_comm_schedule(target: LintTarget, *,
                       expect_ppermute: int | None = None,
                       min_ppermute: int | None = None,
                       forbid_primitives: Iterable[str] = (),
                       kernel_names: Iterable[str] = (),
                       expect_kernel_calls: int | None = None,
                       ) -> list[Finding]:
    """The gossip schedule must lower to the expected communication pattern.

    Generalizes the hand-rolled asserts from the mix tests: a fused k-hop
    ring mix is one halo ``ppermute`` per side plus one megakernel call
    site; the unfused path is one permute pair and one kernel call per hop;
    and the ring path must never lower a dense ``dot_general`` (the W-matmul
    belongs to the ``full`` topology only).
    """
    findings = []
    cj = target.jaxpr
    if expect_ppermute is not None:
        n = count_primitive(cj, "ppermute")
        if n != expect_ppermute:
            findings.append(Finding(
                "comm-schedule", target.name,
                f"expected {expect_ppermute} ppermute(s), found {n}"))
    if min_ppermute is not None:
        n = count_primitive(cj, "ppermute")
        if n < min_ppermute:
            findings.append(Finding(
                "comm-schedule", target.name,
                f"expected at least {min_ppermute} ppermute(s), found {n}"))
    for prim in forbid_primitives:
        n = count_primitive(cj, prim)
        if n:
            findings.append(Finding(
                "comm-schedule", target.name,
                f"forbidden primitive `{prim}` appears {n} time(s) on this "
                "path"))
    if expect_kernel_calls is not None:
        n = kernel_call_sites(cj, kernel_names)
        if n != expect_kernel_calls:
            findings.append(Finding(
                "comm-schedule", target.name,
                f"expected {expect_kernel_calls} kernel call site(s) for "
                f"{sorted(kernel_names)}, found {n}"))
    return findings


RULES: dict[str, Callable[..., list[Finding]]] = {
    "weak-type-leak": rule_weak_type_leak,
    "effect-in-quiet-path": rule_effect_in_quiet_path,
    "donation-miss": rule_donation_miss,
    "comm-schedule": rule_comm_schedule,
}


def lint(target: LintTarget, rules: Iterable[Any]) -> list[Finding]:
    """Run rule specs (``"name"`` or ``("name", {params})``) over a target."""
    findings: list[Finding] = []
    for spec in rules:
        name, params = (spec, {}) if isinstance(spec, str) else spec
        findings.extend(RULES[name](target, **params))
    return findings


def assert_jaxpr_rule(rule: str, *, name: str = "<target>",
                      fn: Callable | None = None, args: tuple = (),
                      jaxpr: Any = None, state: Any = None,
                      donate_argnums: Iterable[int] = (),
                      **params) -> Any:
    """Trace ``fn`` (or take ``jaxpr``) and assert ``rule`` finds nothing.

    Returns the ClosedJaxpr so callers can chain further rules without
    retracing.  Raises ``AssertionError`` listing every finding otherwise.
    """
    if jaxpr is None and fn is not None:
        jaxpr = jax.make_jaxpr(fn)(*args)
    target = LintTarget(name=name, jaxpr=jaxpr, state=state, args=args,
                        donate_argnums=tuple(donate_argnums))
    findings = RULES[rule](target, **params)
    if findings:
        raise AssertionError(
            "jaxpr lint failed:\n" + "\n".join(f"  {f}" for f in findings))
    return jaxpr


# --------------------------------------------------------------------------
# runtime recompile sentinel
# --------------------------------------------------------------------------

class RecompileError(AssertionError):
    """A watched jitted function retraced under fixed shapes."""


class RecompileSentinel:
    """Fails if a step function retraces under fixed shapes.

    Two modes, composable in one sentinel:

    - ``wrap(fn, label=...)`` jits ``fn`` through a trace-counting shim —
      the counter increments at *trace* time, so a second hit under
      unchanged shapes/dtypes is caught exactly.
    - ``watch(label, jitted)`` snapshots an existing jitted function's
      compile-cache size (``_cache_size``); growth beyond ``max_traces``
      since the snapshot trips ``check()``.  This is how the serve tests
      watch ``ServeEngine``'s page-bucketed prefill cache without touching
      engine internals.
    """

    def __init__(self) -> None:
        self._trace_counts: dict[str, int] = {}
        self._watched: dict[str, Any] = {}
        self._baseline: dict[str, int] = {}

    def wrap(self, fn: Callable, label: str | None = None, **jit_kwargs):
        label = label or getattr(fn, "__name__", "fn")
        self._trace_counts.setdefault(label, 0)

        def counted(*a, **k):
            self._trace_counts[label] += 1
            return fn(*a, **k)

        counted.__name__ = getattr(fn, "__name__", "fn")
        return jax.jit(counted, **jit_kwargs)

    def watch(self, label: str, jitted: Any) -> Any:
        if not hasattr(jitted, "_cache_size"):
            raise TypeError(f"{label}: not a jitted function "
                            f"(no _cache_size): {type(jitted).__name__}")
        self._watched[label] = jitted
        self._baseline[label] = jitted._cache_size()
        return jitted

    def traces(self, label: str) -> int:
        if label in self._watched:
            return self._watched[label]._cache_size() - self._baseline[label]
        return self._trace_counts[label]

    def labels(self) -> list[str]:
        return sorted(set(self._trace_counts) | set(self._watched))

    def check(self, max_traces: int = 1) -> None:
        over = [f"{lbl}: {self.traces(lbl)} traces (max {max_traces})"
                for lbl in self.labels() if self.traces(lbl) > max_traces]
        if over:
            raise RecompileError(
                "recompile sentinel tripped — a step retraced under fixed "
                "shapes: " + "; ".join(over))
