"""CLI: ``python -m repro.analysis [--rules ...] [--hw tpu_v5e]``.

Runs every registered analysis pass over the repo's entry points and exits
nonzero on violations.  ``--selftest`` instead verifies the analyzers fire
on seeded known-bad fixtures (weak_type init leaf, over-VMEM block config,
sub-stochastic W_t, collapsed donation, quiet-path io_callback) — the CI
``analysis`` job runs both modes.  ``--json`` writes the findings summary
(default ``experiments/bench/analysis.json``, consumed by
``benchmarks/build_report.py`` §Static analysis).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.analysis import entrypoints
from repro.launch import roofline

_DEFAULT_JSON = os.path.join("experiments", "bench", "analysis.json")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--rules", nargs="*", default=None,
                    help="rule names to run (default: all); e.g. "
                         "--rules weak-type-leak vmem-budget")
    ap.add_argument("--hw", default=None, choices=sorted(roofline.HARDWARE),
                    help="hardware model for the VMEM budget "
                         "(default: REPRO_HW or tpu_v5e)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help=f"write a findings summary (default "
                         f"{_DEFAULT_JSON}; '-' to skip)")
    ap.add_argument("--selftest", action="store_true",
                    help="verify each pass fires on seeded bad fixtures")
    args = ap.parse_args(argv)

    if args.selftest:
        failures = entrypoints.selftest()
        for f in failures:
            print(f"SELFTEST FAIL: {f}")
        if not failures:
            print("selftest ok: every analyzer caught its seeded fixture")
        return 1 if failures else 0

    hw = roofline.get_hardware(args.hw)
    rules = set(args.rules) if args.rules else None
    t0 = time.time()
    results = entrypoints.run_passes(rules=rules, hw=hw)
    elapsed = time.time() - t0

    n_findings = 0
    for pass_name, findings in results.items():
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"{pass_name:<20} {status}")
        for f in findings:
            print(f"  {f}")
        n_findings += len(findings)

    summary = {
        "hw": hw.name,
        "rules": sorted(rules) if rules else "all",
        "elapsed_s": round(elapsed, 2),
        "passes": {name: [f.to_json() for f in fs]
                   for name, fs in results.items()},
        "n_findings": n_findings,
    }
    json_path = args.json or _DEFAULT_JSON
    if json_path != "-":
        os.makedirs(os.path.dirname(json_path) or ".", exist_ok=True)
        with open(json_path, "w") as fh:
            json.dump(summary, fh, indent=1)
    print(f"{len(results)} passes, {n_findings} finding(s), "
          f"{elapsed:.1f}s [{hw.name}]")
    return 1 if n_findings else 0


if __name__ == "__main__":
    sys.exit(main())
