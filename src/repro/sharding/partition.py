"""Parameter / batch / state partitioning rules.

Training mesh axes: ("pod",) "node", "fsdp", "model"
  * every training-state leaf is node-stacked: axis 0 -> ("pod","node")
  * within a node replica: megatron-ish — last dim to "model" when
    divisible, first remaining divisible dim to "fsdp" (ZeRO-style);
    MoE expert stacks put the expert dim on "model" (expert parallelism).
  * anything that doesn't divide cleanly is replicated on that axis
    (e.g. smollm's 9 heads vs a 16-way model axis) — correctness never
    depends on a sharding, only memory/perf do.

Serving mesh axes: ("pod",) "data", "model"
  * params: last dim "model", first remaining divisible dim "data"
    (weight-gathered serving); batch dims over ("pod","data");
  * KV caches: batch over ("pod","data") when divisible, otherwise the
    *sequence* dim is sharded (long_500k batch=1 -> sequence-parallel cache).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# params whose *first non-node* dim is an expert stack
_EXPERT_RE = re.compile(r"moe/(w_gate|w_up|w_down)$")
_ROUTER_RE = re.compile(r"moe/router$")


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def path_of(path_tuple) -> str:
    return "/".join(_key_str(k) for k in path_tuple)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _assign(shape: tuple[int, ...], axes: list[tuple[str, int]],
            prefer_last_first: bool = True) -> list[Optional[str]]:
    """Greedy: give each mesh axis a distinct divisible tensor dim."""
    spec: list[Optional[str]] = [None] * len(shape)
    order = list(range(len(shape)))
    if prefer_last_first:
        order = order[::-1]
    for ax_name, ax_size in axes:
        if ax_size == 1:
            continue
        for d in order:
            if spec[d] is None and shape[d] % ax_size == 0 and shape[d] >= ax_size:
                spec[d] = ax_name
                break
    return spec


def train_param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
                     multi_pod: bool) -> P:
    """Spec for a node-stacked training parameter (axis 0 = node)."""
    node_axes = ("pod", "node") if multi_pod else ("node",)
    inner = shape[1:]
    model, fsdp = _axis_size(mesh, "model"), _axis_size(mesh, "fsdp")
    if len(inner) == 0:
        return P(node_axes)
    if len(inner) == 1:
        # 1-D (norm scales, biases): shard over fsdp when big enough
        if inner[0] % fsdp == 0 and inner[0] >= 1024 and fsdp > 1:
            return P(node_axes, "fsdp")
        return P(node_axes)
    if _EXPERT_RE.search(path) and inner[0] % model == 0:
        # (E, d, f): experts -> model, then fsdp on the biggest remaining dim
        rest = _assign(inner[1:], [("fsdp", fsdp)])
        return P(node_axes, "model", *rest)
    spec = _assign(inner, [("model", model), ("fsdp", fsdp)])
    return P(node_axes, *spec)


def serve_param_spec(path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    model, data = _axis_size(mesh, "model"), _axis_size(mesh, "data")
    if len(shape) <= 1:
        return P()
    if _EXPERT_RE.search(path) and shape[0] % model == 0:
        rest = _assign(shape[1:], [("data", data)])
        return P("model", *rest)
    spec = _assign(shape, [("model", model), ("data", data)])
    return P(*spec)


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# state leaves that are per-run scalars/PRNG material, never node-stacked
_REPLICATED_STATE_RE = re.compile(r"(^|/)comm/(key|deltas)(/|$)")


def train_state_shardings(state_shapes: PyTree, mesh: Mesh,
                          multi_pod: bool) -> PyTree:
    """Shardings for a GDAState (or baseline state) pytree of ShapeDtype.

    Every node-stacked leaf — x/y/u/v, the gx/gy memories, AND the comms
    CHOCO hats inside ``CommState`` — puts axis 0 on the node mesh axes, so
    the shard_map mix backend's in_specs line up with the state layout and
    no reshard happens at the mix boundary.  Non-node leaves (the PRNG key,
    adaptive-gamma deltas, step counters, anything whose leading dim does
    not divide over the node axes) are replicated: correctness never depends
    on a sharding, only memory/perf do.
    """
    node_axes = ("pod", "node") if multi_pod else ("node",)
    n_node = int(np.prod([_axis_size(mesh, a) for a in node_axes]))

    def one(path_tuple, leaf):
        path = path_of(path_tuple)
        shape = leaf.shape
        if len(shape) == 0 or _REPLICATED_STATE_RE.search(path):
            return _named(mesh, P())
        if shape[0] % n_node or shape[0] < n_node:
            return _named(mesh, P())        # not node-stacked: replicate
        if len(shape) <= 2:
            return _named(mesh, P(node_axes))
        return _named(mesh, train_param_spec(path, shape, mesh, multi_pod))

    return jax.tree_util.tree_map_with_path(one, state_shapes)


def train_batch_shardings(batch_shapes: PyTree, mesh: Mesh,
                          multi_pod: bool) -> PyTree:
    node_axes = ("pod", "node") if multi_pod else ("node",)
    fsdp = _axis_size(mesh, "fsdp")

    def one(path_tuple, leaf):
        shape = leaf.shape
        if len(shape) >= 2 and shape[1] % fsdp == 0:
            return _named(mesh, P(node_axes, "fsdp"))
        return _named(mesh, P(node_axes))

    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def serve_param_shardings(param_shapes: PyTree, mesh: Mesh) -> PyTree:
    def one(path_tuple, leaf):
        return _named(mesh, serve_param_spec(path_of(path_tuple), leaf.shape,
                                             mesh))
    return jax.tree_util.tree_map_with_path(one, param_shapes)


def serve_batch_shardings(spec_tree: PyTree, mesh: Mesh,
                          multi_pod: bool) -> PyTree:
    """token/position/cache/frontend shardings for serve_step inputs."""
    data_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = int(np.prod([_axis_size(mesh, a) for a in data_axes]))
    model = _axis_size(mesh, "model")

    def one(path_tuple, leaf):
        path = path_of(path_tuple)
        shape = leaf.shape
        if len(shape) == 0:
            return _named(mesh, P())
        batch_ok = shape[0] % n_data == 0 and shape[0] >= n_data
        if len(shape) == 1:
            return _named(mesh, P(data_axes if batch_ok else None))
        spec: list = [data_axes if batch_ok else None] + [None] * (len(shape) - 1)
        if not batch_ok and len(shape) >= 2 and shape[1] % n_data == 0 \
                and shape[1] >= n_data:
            spec[1] = data_axes            # sequence-parallel cache (B=1)
        # kv-head / hidden dims onto model when divisible
        for d in range(len(shape) - 1, 1, -1):
            if spec[d] is None and shape[d] % model == 0 and shape[d] >= model:
                spec[d] = "model"
                break
        return _named(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, spec_tree)


def project_params_to_manifold(params: PyTree, map_or_mask: PyTree) -> PyTree:
    """Map every constrained leaf to a feasible starting point (used once at
    init so every leaf the policy selects starts feasible, regardless of its
    initializer).  Accepts a geometry manifold_map or a legacy bool mask.

    Each geometry picks its own ``feasible_init``: Stiefel/Grassmann use QR
    orthonormalization (exact feasibility regardless of the raw
    initializer's conditioning — polar/NS inverse-sqrt loses digits when
    x^T x has tiny eigenvalues, e.g. 1/sqrt(d)-scaled dense inits; the
    algorithm only needs x0 ON the manifold, not the nearest point),
    oblique/sphere normalize, Euclidean passes through."""
    from repro import geometry

    return jax.tree.map(lambda m, x: m.feasible_init(x),
                        geometry.as_manifold_map(map_or_mask), params)
