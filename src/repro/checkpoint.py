"""Minimal dependency-free pytree checkpointing (npz + path manifest).

Layout:  <dir>/step_<n>.npz  with keys 'p<i>' in flatten order, plus a
'__paths__' manifest array for structural validation on restore.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _paths(tree: PyTree) -> list[str]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, _ in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        out.append("/".join(parts))
    return out


def save(directory: str, step: int, tree: PyTree) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves = jax.tree.leaves(tree)
    arrays, dtypes = {}, []
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V" or a.dtype.name == "bfloat16":
            # npz cannot roundtrip extended dtypes (bf16 etc.) — store the
            # raw bits and re-view on restore using the dtype manifest.
            a = a.view(np.uint16 if a.dtype.itemsize == 2 else np.uint8)
        arrays[f"p{i}"] = a
    arrays["__paths__"] = np.array(json.dumps(_paths(tree)))
    arrays["__dtypes__"] = np.array(json.dumps(dtypes))
    path = os.path.join(directory, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **arrays)
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (validates the path manifest)."""
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path, allow_pickle=False)
    want = _paths(like)
    have = json.loads(str(data["__paths__"]))
    if want != have:
        raise ValueError(
            f"checkpoint structure mismatch: {len(have)} leaves saved vs "
            f"{len(want)} expected; first diff: "
            f"{next((a, b) for a, b in zip(have + [''], want + ['']) if a != b)}")
    dtypes = json.loads(str(data["__dtypes__"])) if "__dtypes__" in data \
        else [None] * len(want)
    leaves = []
    for i in range(len(want)):
        a = data[f"p{i}"]
        dt = dtypes[i]
        if dt is not None and str(a.dtype) != dt:
            import ml_dtypes
            a = a.view(np.dtype(getattr(ml_dtypes, dt, dt)))
        leaves.append(jnp.asarray(a))
    return jax.tree.unflatten(jax.tree.structure(like), leaves)
