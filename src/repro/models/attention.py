"""Attention sublayers: GQA (w/ sliding window), MLA (DeepSeek-V2), cross-attn.

Pure functions over param dicts.  The score/softmax/PV core goes through
``repro.kernels.ops.flash_attention`` (Pallas on TPU, blockwise-jnp
elsewhere).  Prefill returns a KV cache; ``decode`` consumes/updates it.

KV caches are ring buffers: slot = position % cache_len, with an explicit
``pos`` array (-1 = empty) used for masking, so sliding-window layers can
allocate ``cache_len == window`` even when the sequence is 500k tokens.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import AttnSpec, ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init, orthogonal_init

Array = jax.Array


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, spec: AttnSpec, dtype=jnp.float32):
    hd, h, hkv, d = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "wq": orthogonal_init(ks[0], d, h * hd, dtype),
        "wk": orthogonal_init(ks[1], d, hkv * hd, dtype),
        "wv": orthogonal_init(ks[2], d, hkv * hd, dtype),
        "wo": orthogonal_init(ks[3], h * hd, d, dtype),
    }
    if spec.cross_attn:
        p["wk_x"] = orthogonal_init(ks[4], d, hkv * hd, dtype)
        p["wv_x"] = orthogonal_init(ks[5], d, hkv * hd, dtype)
    return p


def gqa_prefill(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
                positions: Array, *, make_cache: bool = False,
                cache_len: int = 0):
    """x: (B, S, d).  Returns (y, cache | None)."""
    b, s, d = x.shape
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, hkv, hd)
    v = (x @ params["wv"]).reshape(b, s, hkv, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    y = ops.flash_attention(q, k, v, causal=True, window=spec.sliding_window,
                            q_positions=positions, kv_positions=positions)
    out = y.reshape(b, s, h * hd) @ params["wo"]

    cache = None
    if make_cache:
        cl = cache_len or s
        cache = _new_kv_cache(b, cl, hkv, hd, k.dtype)
        cache = _cache_write_many(cache, k, v, positions)
    return out, cache


def gqa_decode(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
               position: Array, cache: dict):
    """One-token decode.  x: (B, 1, d); position: (B,) int32."""
    b, _, d = x.shape
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ params["wv"]).reshape(b, 1, hkv, hd)
    pos2 = position[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)

    cache = _cache_write_one(cache, k[:, 0], v[:, 0], position)
    y = ops.flash_attention(q, cache["k"], cache["v"], causal=True,
                            window=spec.sliding_window, q_positions=pos2,
                            kv_positions=cache["pos"])
    return y.reshape(b, 1, h * hd) @ params["wo"], cache


def gqa_decode_paged(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
                     pos_bt, cache: dict):
    """One-token decode against a paged KV pool (``repro.serve.kv_cache``).

    ``pos_bt`` is ``(position, block_table)``: per-slot positions (S,) int32
    of the *incoming* token, and the shared block table (S, M) int32 — they
    ride together through ``decode_step``'s opaque ``position`` argument.
    ``cache`` holds this layer's ``{"k_pages", "v_pages"}`` pools; the new
    token's K/V are scattered into the slot's current page (inactive slots
    land on the dump page 0), then attention runs through the block-table
    gather kernel with ``seq_lens = position + 1``."""
    position, block_table = pos_bt
    s, _, _ = x.shape
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ params["wq"]).reshape(s, 1, h, hd)
    k = (x @ params["wk"]).reshape(s, 1, hkv, hd)
    v = (x @ params["wv"]).reshape(s, 1, hkv, hd)
    pos2 = position[:, None]
    q = apply_rope(q, pos2, cfg.rope_theta)
    k = apply_rope(k, pos2, cfg.rope_theta)

    ps = cache["k_pages"].shape[1]
    page = jnp.maximum(block_table[jnp.arange(s), position // ps], 0)
    off = position % ps
    kp = cache["k_pages"].at[page, off].set(k[:, 0])
    vp = cache["v_pages"].at[page, off].set(v[:, 0])
    y = ops.paged_decode_attention(q[:, 0], kp, vp, block_table,
                                   position + 1, window=spec.sliding_window)
    return (y.reshape(s, 1, h * hd) @ params["wo"],
            {"k_pages": kp, "v_pages": vp})


def cross_attend(params, x: Array, cfg: ModelConfig, frontend_kv: dict):
    """Cross-attention onto precomputed frontend K/V (not causal)."""
    b, s, d = x.shape
    hd, h = cfg.hd, cfg.n_heads
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    y = ops.flash_attention(q, frontend_kv["k"], frontend_kv["v"],
                            causal=False, q_positions=None, kv_positions=None)
    return y.reshape(b, s, h * hd) @ params["wo"]


def make_frontend_kv(params, embeds: Array, cfg: ModelConfig) -> dict:
    """Project frontend embeddings (B, N, d_model) once into K/V."""
    b, n, _ = embeds.shape
    hd, hkv = cfg.hd, cfg.n_kv_heads
    return {
        "k": (embeds @ params["wk_x"]).reshape(b, n, hkv, hd),
        "v": (embeds @ params["wv_x"]).reshape(b, n, hkv, hd),
    }


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention, DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, spec: AttnSpec, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    dq, dkv = spec.q_lora_rank, spec.kv_lora_rank
    dn, dr, dv = spec.qk_nope_head_dim, spec.qk_rope_head_dim, spec.v_head_dim
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[1], d, dkv + dr, dtype=dtype),        # down: c_kv + k_rope
        "w_uk": orthogonal_init(ks[2], dkv, h * dn, dtype),          # up: K (nope)
        "w_uv": orthogonal_init(ks[3], dkv, h * dv, dtype),          # up: V
        "wo": orthogonal_init(ks[4], h * dv, d, dtype),
    }
    if dq:
        p["w_dq"] = dense_init(ks[0], d, dq, dtype=dtype)
        p["w_uq"] = orthogonal_init(ks[5], dq, h * (dn + dr), dtype)
    else:
        p["w_uq"] = orthogonal_init(ks[5], d, h * (dn + dr), dtype)
    return p


def _mla_qkv(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
             positions: Array):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = spec.qk_nope_head_dim, spec.qk_rope_head_dim, spec.v_head_dim
    cq = x @ params["w_dq"] if "w_dq" in params else x
    q = (cq @ params["w_uq"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    ckv = x @ params["w_dkv"]                                    # (B,S,dkv+dr)
    c_kv, k_rope = ckv[..., :spec.kv_lora_rank], ckv[..., spec.kv_lora_rank:]
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return q, c_kv, k_rope


def _mla_expand_kv(params, c_kv: Array, k_rope: Array, spec: AttnSpec, h: int):
    b, t, _ = c_kv.shape
    dn, dr, dv = spec.qk_nope_head_dim, spec.qk_rope_head_dim, spec.v_head_dim
    k_nope = (c_kv @ params["w_uk"]).reshape(b, t, h, dn)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, t, h, dr))], axis=-1)
    v = (c_kv @ params["w_uv"]).reshape(b, t, h, dv)
    return k, v


def mla_prefill(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
                positions: Array, *, make_cache: bool = False,
                cache_len: int = 0):
    b, s, _ = x.shape
    h = cfg.n_heads
    dv = spec.v_head_dim
    q, c_kv, k_rope = _mla_qkv(params, x, cfg, spec, positions)
    k, v = _mla_expand_kv(params, c_kv, k_rope, spec, h)
    y = ops.flash_attention(q, k, v, causal=True, window=spec.sliding_window,
                            q_positions=positions, kv_positions=positions,
                            softmax_scale=(spec.qk_nope_head_dim
                                           + spec.qk_rope_head_dim) ** -0.5)
    out = y.reshape(b, s, h * dv) @ params["wo"]
    cache = None
    if make_cache:
        cl = cache_len or s
        cache = {
            "c_kv": jnp.zeros((b, cl, spec.kv_lora_rank), c_kv.dtype),
            "k_rope": jnp.zeros((b, cl, spec.qk_rope_head_dim), k_rope.dtype),
            "pos": jnp.full((b, cl), -1, jnp.int32),
        }
        slots = positions % cl
        upd = lambda buf, val: jax.vmap(
            lambda bb, vv, ss: bb.at[ss].set(vv))(buf, val, slots)
        cache = {"c_kv": upd(cache["c_kv"], c_kv),
                 "k_rope": upd(cache["k_rope"], k_rope),
                 "pos": upd(cache["pos"], positions.astype(jnp.int32))}
    return out, cache


def mla_decode(params, x: Array, cfg: ModelConfig, spec: AttnSpec,
               position: Array, cache: dict):
    """Decode with the *compressed* cache (c_kv + shared k_rope) — MLA's
    memory saving; K/V are re-expanded blockwise at attention time."""
    b, _, _ = x.shape
    h = cfg.n_heads
    dv = spec.v_head_dim
    pos2 = position[:, None]
    q, c_kv, k_rope = _mla_qkv(params, x, cfg, spec, pos2)

    slot = position % cache["c_kv"].shape[1]
    cache = {
        "c_kv": jax.vmap(lambda bb, vv, ss: bb.at[ss].set(vv))(
            cache["c_kv"], c_kv[:, 0], slot),
        "k_rope": jax.vmap(lambda bb, vv, ss: bb.at[ss].set(vv))(
            cache["k_rope"], k_rope[:, 0], slot),
        "pos": jax.vmap(lambda bb, vv, ss: bb.at[ss].set(vv))(
            cache["pos"], position.astype(jnp.int32), slot),
    }
    k, v = _mla_expand_kv(params, cache["c_kv"], cache["k_rope"], spec, h)
    y = ops.flash_attention(q, k, v, causal=True, window=spec.sliding_window,
                            q_positions=pos2, kv_positions=cache["pos"],
                            softmax_scale=(spec.qk_nope_head_dim
                                           + spec.qk_rope_head_dim) ** -0.5)
    return y.reshape(b, 1, h * dv) @ params["wo"], cache


# ---------------------------------------------------------------------------
# KV-cache plumbing (ring buffer with explicit positions)
# ---------------------------------------------------------------------------


def _new_kv_cache(b: int, cache_len: int, hkv: int, hd: int, dtype) -> dict:
    return {
        "k": jnp.zeros((b, cache_len, hkv, hd), dtype),
        "v": jnp.zeros((b, cache_len, hkv, hd), dtype),
        "pos": jnp.full((b, cache_len), -1, jnp.int32),
    }


def _cache_write_many(cache: dict, k: Array, v: Array, positions: Array) -> dict:
    cl = cache["k"].shape[1]
    slots = positions % cl
    upd = lambda buf, val: jax.vmap(lambda bb, vv, ss: bb.at[ss].set(vv))(buf, val, slots)
    return {"k": upd(cache["k"], k), "v": upd(cache["v"], v),
            "pos": upd(cache["pos"], positions.astype(jnp.int32))}


def _cache_write_one(cache: dict, k1: Array, v1: Array, position: Array) -> dict:
    cl = cache["k"].shape[1]
    slot = position % cl
    w = lambda buf, val: jax.vmap(lambda bb, vv, ss: bb.at[ss].set(vv))(buf, val, slot)
    return {"k": w(cache["k"], k1), "v": w(cache["v"], v1),
            "pos": w(cache["pos"], position.astype(jnp.int32))}


def attn_cache_len(spec: AttnSpec, seq_len: int) -> int:
    if spec.sliding_window is not None:
        return min(seq_len, spec.sliding_window)
    return seq_len


def init_attention(key, cfg: ModelConfig, spec: AttnSpec, dtype=jnp.float32):
    if spec.kind == "mla":
        return init_mla(key, cfg, spec, dtype)
    return init_gqa(key, cfg, spec, dtype)
