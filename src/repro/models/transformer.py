"""Model assembly: stages of block supercells, executed with lax.scan.

Three entry points (all pure):

  * ``forward``      — training/prefill logits over a full sequence
                       (``mode="prefill"`` additionally returns caches);
  * ``decode_step``  — one new token against per-layer caches;
  * ``init_params`` / ``init_cache`` — constructors (init_cache is
                       shape-only: usable under ``jax.eval_shape`` for the
                       dry-run's ShapeDtypeStruct inputs).

Layer stacking: a :class:`Stage` repeats a supercell ``repeat`` times; its
parameters (and caches) carry a leading ``repeat`` axis and the supercell
body compiles once (flat compile time in depth — 62-layer Gemma compiles a
6-block body).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig, Stage
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (dense_init, embed_init, rmsnorm,
                                 rmsnorm_init, swiglu, swiglu_init)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig, spec: BlockSpec, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.kind in ("attn", "moe_attn"):
        p["attn"] = attn_mod.init_attention(ks[0], cfg, spec.attn, dtype)
        if spec.attn.cross_attn:
            p["ln_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if spec.kind == "moe_attn":
            p["moe"] = moe_mod.init_moe(ks[1], cfg, spec.moe, dtype)
        elif spec.has_mlp and cfg.d_ff > 0:
            p["mlp"] = swiglu_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    elif spec.kind == "mamba":
        p["mamba"] = ssm_mod.init_mamba(ks[0], cfg, spec.ssm, dtype)
    elif spec.kind == "mlstm":
        p["mlstm"] = xlstm_mod.init_mlstm(ks[0], cfg, spec.xlstm, dtype)
    elif spec.kind == "slstm":
        p["slstm"] = xlstm_mod.init_slstm(ks[0], cfg, spec.xlstm, dtype)
    else:
        raise ValueError(spec.kind)
    return p


def init_stage(key, cfg: ModelConfig, stage: Stage, dtype=jnp.float32) -> dict:
    def one(k):
        kk = jax.random.split(k, len(stage.blocks))
        return {f"b{i}": init_block(kk[i], cfg, sp, dtype)
                for i, sp in enumerate(stage.blocks)}
    if stage.repeat == 1:
        return one(key)
    keys = jax.random.split(key, stage.repeat)
    per = [one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(cfg.stages) + 4)
    v_eff = cfg.padded_vocab
    p: dict = {}
    if cfg.n_codebooks > 1:
        p["embed"] = jnp.stack([
            embed_init(k, v_eff, cfg.d_model, dtype)
            for k in jax.random.split(ks[0], cfg.n_codebooks)])
    else:
        p["embed"] = embed_init(ks[0], v_eff, cfg.d_model, dtype)
    if cfg.frontend is not None:
        p["frontend_proj"] = dense_init(ks[1], cfg.frontend.embed_dim,
                                        cfg.d_model, dtype=dtype)
    p["stages"] = {f"s{i}": init_stage(ks[2 + i], cfg, st, dtype)
                   for i, st in enumerate(cfg.stages)}
    p["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            p["lm_head"] = jnp.stack([
                dense_init(k, cfg.d_model, v_eff, dtype=dtype)
                for k in jax.random.split(ks[-1], cfg.n_codebooks)])
        else:
            p["lm_head"] = dense_init(ks[-1], cfg.d_model, v_eff, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# caches (shape-only constructors)
# ---------------------------------------------------------------------------


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, bsz: int,
                     cache_seq_len: int, dtype) -> Optional[dict]:
    if spec.kind in ("attn", "moe_attn"):
        a = spec.attn
        cl = attn_mod.attn_cache_len(a, cache_seq_len)
        if a.kind == "mla":
            return {
                "c_kv": jnp.zeros((bsz, cl, a.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((bsz, cl, a.qk_rope_head_dim), dtype),
                "pos": jnp.full((bsz, cl), -1, jnp.int32),
            }
        return {
            "k": jnp.zeros((bsz, cl, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((bsz, cl, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.full((bsz, cl), -1, jnp.int32),
        }
    if spec.kind == "mamba":
        d_inner = spec.ssm.expand * cfg.d_model
        h = d_inner // spec.ssm.head_dim
        conv_c = d_inner + 2 * spec.ssm.n_groups * spec.ssm.d_state
        return {
            "ssm": jnp.zeros((bsz, h, spec.ssm.d_state, spec.ssm.head_dim),
                             jnp.float32),
            "conv": jnp.zeros((bsz, spec.ssm.d_conv - 1, conv_c), dtype),
        }
    if spec.kind == "mlstm":
        d_inner = int(cfg.d_model * spec.xlstm.proj_factor)
        dk = d_inner // cfg.n_heads
        return {
            "C": jnp.zeros((bsz, cfg.n_heads, dk, dk), jnp.float32),
            "n": jnp.zeros((bsz, cfg.n_heads, dk), jnp.float32),
            "m": jnp.full((bsz, cfg.n_heads), -1e30, jnp.float32),
            "conv": jnp.zeros((bsz, spec.xlstm.conv_window - 1, d_inner), dtype),
        }
    if spec.kind == "slstm":
        z = jnp.zeros((bsz, cfg.d_model), jnp.float32)
        return {"state": (z, jnp.ones_like(z), z,
                          jnp.full((bsz, cfg.d_model), -1e30, jnp.float32))}
    raise ValueError(spec.kind)


def init_cache(cfg: ModelConfig, bsz: int, cache_seq_len: int,
               dtype=jnp.float32) -> dict:
    caches = {}
    for i, st in enumerate(cfg.stages):
        cell = {f"b{j}": init_block_cache(cfg, sp, bsz, cache_seq_len, dtype)
                for j, sp in enumerate(st.blocks)}
        if st.repeat > 1:
            cell = jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (st.repeat, *x.shape)), cell)
        caches[f"s{i}"] = cell
    return caches


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def apply_block(params: dict, cfg: ModelConfig, spec: BlockSpec, x: Array,
                positions: Array, mode: str, cache: Optional[dict],
                frontend_embeds: Optional[Array],
                cache_len: Optional[int] = None):
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)

    if spec.kind in ("attn", "moe_attn"):
        a = spec.attn
        if mode == "decode":
            if a.kind != "mla" and isinstance(cache, dict) \
                    and "k_pages" in cache:
                # paged serving path: ``positions`` is (position, block_table)
                y, cache = attn_mod.gqa_decode_paged(
                    params["attn"], h, cfg, a, positions, cache)
            else:
                fn = attn_mod.mla_decode if a.kind == "mla" \
                    else attn_mod.gqa_decode
                y, cache = fn(params["attn"], h, cfg, a, positions, cache)
        else:
            fn = attn_mod.mla_prefill if a.kind == "mla" else attn_mod.gqa_prefill
            cl = attn_mod.attn_cache_len(a, cache_len or x.shape[1])
            y, cache = fn(params["attn"], h, cfg, a, positions,
                          make_cache=(mode == "prefill"), cache_len=cl)
        x = x + y
        if a.cross_attn and frontend_embeds is not None:
            hx = rmsnorm(params["ln_x"], x, cfg.norm_eps)
            fkv = attn_mod.make_frontend_kv(params["attn"], frontend_embeds, cfg)
            x = x + attn_mod.cross_attend(params["attn"], hx, cfg, fkv)
        h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if spec.kind == "moe_attn":
            y2, aux = moe_mod.apply_moe(params["moe"], h2, spec.moe)
            x = x + y2
        elif "mlp" in params:
            x = x + swiglu(params["mlp"], h2)
        return x, aux, cache

    if spec.kind == "mamba":
        if mode == "decode":
            y, cache = ssm_mod.mamba_decode(params["mamba"], h, cfg, spec.ssm, cache)
        else:
            y, cache = ssm_mod.mamba_prefill(params["mamba"], h, cfg, spec.ssm,
                                             make_cache=(mode == "prefill"))
        return x + y, aux, cache

    if spec.kind == "mlstm":
        if mode == "decode":
            y, cache = xlstm_mod.mlstm_decode(params["mlstm"], h, cfg,
                                              spec.xlstm, cache)
        else:
            y, cache = xlstm_mod.mlstm_prefill(params["mlstm"], h, cfg,
                                               spec.xlstm,
                                               make_cache=(mode == "prefill"))
        return x + y, aux, cache

    if spec.kind == "slstm":
        if mode == "decode":
            y, cache = xlstm_mod.slstm_decode(params["slstm"], h, cfg,
                                              spec.xlstm, cache)
        else:
            y, cache = xlstm_mod.slstm_prefill(params["slstm"], h, cfg,
                                               spec.xlstm,
                                               make_cache=(mode == "prefill"))
        return x + y, aux, cache

    raise ValueError(spec.kind)


def _apply_supercell(cell_params: dict, cfg: ModelConfig, stage: Stage,
                     x: Array, positions: Array, mode: str,
                     cell_cache: Optional[dict],
                     frontend_embeds: Optional[Array],
                     cache_len: Optional[int] = None):
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {}
    for j, sp in enumerate(stage.blocks):
        bc = None if cell_cache is None else cell_cache.get(f"b{j}")
        x, aux, nc = apply_block(cell_params[f"b{j}"], cfg, sp, x, positions,
                                 mode, bc, frontend_embeds, cache_len)
        aux_total += aux
        new_caches[f"b{j}"] = nc
    return x, aux_total, new_caches


def apply_stage(stage_params: dict, cfg: ModelConfig, stage: Stage, x: Array,
                positions: Array, mode: str, stage_cache: Optional[dict],
                frontend_embeds: Optional[Array],
                cache_len: Optional[int] = None):
    want_cache = mode in ("prefill", "decode")

    def cell(p, xx, cc):
        base = functools.partial(_apply_supercell, cfg=cfg, stage=stage,
                                 positions=positions, mode=mode,
                                 frontend_embeds=frontend_embeds,
                                 cache_len=cache_len)
        if cfg.remat and mode == "train":
            ck = jax.checkpoint(
                lambda pp, xxx: base(pp, x=xxx, cell_cache=None),
                policy=jax.checkpoint_policies.nothing_saveable)
            return ck(p, xx)
        return base(p, x=xx, cell_cache=cc)

    if stage.repeat == 1:
        x, aux, nc = cell(stage_params, x, stage_cache)
        return x, aux, (nc if want_cache else None)

    if not cfg.use_scan:
        # unrolled execution (dry-run differential cost analysis: while-loop
        # bodies are cost-counted once, so analysis variants unroll)
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(stage.repeat):
            p_i = jax.tree.map(lambda l: l[i], stage_params)
            c_i = None if stage_cache is None else \
                jax.tree.map(lambda l: l[i], stage_cache)
            x, aux, nc = cell(p_i, x, c_i)
            aux_total += aux
            new_caches.append(nc)
        if want_cache:
            stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *new_caches)
            return x, aux_total, stacked
        return x, aux_total, None

    def body(carry, scanned):
        xx, aux_acc = carry
        if want_cache:
            p, cc = scanned
        else:
            p, cc = scanned, None
        xx, aux, nc = cell(p, xx, cc)
        return (xx, aux_acc + aux), (nc if want_cache else 0)

    if want_cache:
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        (stage_params, stage_cache))
    else:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stage_params)
        caches = None
    return x, aux, caches


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------


def embed_tokens(params: dict, cfg: ModelConfig, tokens: Array) -> Array:
    if cfg.n_codebooks > 1:
        # tokens (B, S, CB): sum of per-codebook embeddings (MusicGen);
        # params["embed"]: (CB, V, d)
        parts = [params["embed"][c][tokens[..., c]]
                 for c in range(cfg.n_codebooks)]
        return sum(parts)
    return params["embed"][tokens]


def unembed(params: dict, cfg: ModelConfig, h: Array) -> Array:
    if cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            return jnp.einsum("bsd,cvd->bscv", h, params["embed"])
        return h @ params["embed"].T
    if cfg.n_codebooks > 1:
        return jnp.einsum("bsd,cdv->bscv", h, params["lm_head"])
    return h @ params["lm_head"]


def project_frontend(params: dict, cfg: ModelConfig,
                     frontend_embeds: Optional[Array]) -> Optional[Array]:
    if frontend_embeds is None or cfg.frontend is None:
        return None
    return frontend_embeds @ params["frontend_proj"]


def forward(params: dict, cfg: ModelConfig, tokens: Array, *,
            frontend_embeds: Optional[Array] = None, mode: str = "train",
            cache_len: Optional[int] = None, last_logits_only: bool = False):
    """tokens: (B, S) or (B, S, CB).  Returns (logits, aux, caches|None)."""
    b, s = tokens.shape[:2]
    x = embed_tokens(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    fe = project_frontend(params, cfg, frontend_embeds)
    aux_total = jnp.zeros((), jnp.float32)
    caches = {}
    for i, st in enumerate(cfg.stages):
        x, aux, nc = apply_stage(params["stages"][f"s{i}"], cfg, st, x,
                                 positions, mode, None, fe, cache_len)
        aux_total += aux
        if nc is not None:
            caches[f"s{i}"] = nc
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_logits_only:
        x = x[:, -1:]
    logits = unembed(params, cfg, x)
    return logits, aux_total, (caches if mode == "prefill" else None)


def decode_step(params: dict, cfg: ModelConfig, token: Array, position: Array,
                caches: dict, *, frontend_embeds: Optional[Array] = None):
    """token: (B,) or (B, CB); position: (B,) int32.  One-step decode.

    Returns (logits (B, V) or (B, CB, V), new_caches).
    """
    tok = token[:, None] if cfg.n_codebooks == 1 else token[:, None, :]
    x = embed_tokens(params, cfg, tok)
    fe = project_frontend(params, cfg, frontend_embeds)
    new_caches = {}
    for i, st in enumerate(cfg.stages):
        x, _, nc = apply_stage(params["stages"][f"s{i}"], cfg, st, x,
                               position, "decode", caches[f"s{i}"], fe)
        new_caches[f"s{i}"] = nc
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits[:, 0], new_caches
