"""Mixture-of-Experts FFN (top-k routing, capacity-bounded, sort-based
dispatch) + shared experts (DeepSeek-V2 style).

Dispatch is the production-style gather/scatter formulation (token sort by
expert, capacity truncation) rather than the (T, E, C) one-hot einsum — the
latter costs O(T·E·C·d) matmul FLOPs and would dominate the roofline with
fake compute.  Expert weights are stacked on a leading E axis, sharded over
the ``model`` mesh axis (expert parallelism); the gather/scatter at the
boundary is where XLA inserts the all-to-all-class collectives that §Perf
iterates on.

Router is always Euclidean (not Stiefel-constrained) — see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoESpec
from repro.models.layers import dense_init

Array = jax.Array


def init_moe(key, cfg: ModelConfig, spec: MoESpec, dtype=jnp.float32):
    d = cfg.d_model
    f = spec.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    e = spec.n_experts
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * (1.0 / d) ** 0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * (1.0 / f) ** 0.5).astype(dtype),
    }
    if spec.n_shared:
        fs = f * spec.n_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, d, fs, dtype=dtype),
            "w_up": dense_init(k2, d, fs, dtype=dtype),
            "w_down": dense_init(k3, fs, d, dtype=dtype),
        }
    return p


def apply_moe(params, x: Array, spec: MoESpec) -> tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss).  Capacity-bounded top-k routing.

    With ``spec.dispatch_groups == G > 1`` the token stream is split into G
    contiguous groups dispatched independently (vmapped): routing stays
    identical per token, capacity becomes per-group, and — when G matches
    the fsdp shard count — the sort/gather/scatter machinery never crosses
    shard boundaries, so GSPMD emits no full-token all-gather.
    """
    b, s, d = x.shape
    t = b * s
    g = spec.dispatch_groups
    if g == -1:
        g = b          # per-sequence dispatch: groups == the batch dim, so
        #                the vmapped axis carries the batch's existing fsdp
        #                sharding and every gather/scatter is shard-local
    if g > 1 and t % g == 0:
        xg = x.reshape(g, t // g, d)
        vmap_kw = {}
        if spec.dispatch_spmd_axis:
            vmap_kw["spmd_axis_name"] = spec.dispatch_spmd_axis
        yg, auxg = jax.vmap(lambda xx: _dispatch_one(params, xx, spec),
                            **vmap_kw)(xg)
        y = yg.reshape(b, s, d)
        return y.astype(x.dtype), jnp.mean(auxg)
    y, aux = _dispatch_one(params, x.reshape(t, d), spec)
    return y.reshape(b, s, d).astype(x.dtype), aux


def _dispatch_one(params, xf: Array, spec: MoESpec) -> tuple[Array, Array]:
    """Sort-based capacity dispatch of a flat (T, d) token group."""
    t, d = xf.shape
    e, k = spec.n_experts, spec.top_k

    logits = xf.astype(jnp.float32) @ params["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)               # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)                   # renormalize

    # -- load-balance auxiliary loss (Switch-style) -------------------------
    frac_tokens = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(1), axis=0)
    mean_probs = probs.mean(axis=0)
    aux = e * jnp.sum(frac_tokens * mean_probs) * spec.router_aux_coef

    # -- sort-based capacity dispatch ---------------------------------------
    cap = int(max(k, round(t * k / e * spec.capacity_factor)))
    flat_expert = expert_idx.reshape(-1)                          # (T*K,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), k)

    order = jnp.argsort(flat_expert, stable=True)
    se, sg, st_ = flat_expert[order], flat_gate[order], flat_token[order]
    # position of each entry within its expert group
    ones = jnp.ones_like(se)
    cum = jnp.cumsum(ones) - 1
    seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    pos_in_e = cum - seg_start[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)          # overflow slot

    # token index per (expert, capacity) slot; e*cap is a dropped-token bin
    token_buf = jnp.full((e * cap + 1,), t, jnp.int32).at[slot].set(
        st_.astype(jnp.int32), mode="drop")
    gate_buf = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sg, 0.0), mode="drop")
    token_buf = token_buf[: e * cap].reshape(e, cap)
    gate_buf = gate_buf[: e * cap].reshape(e, cap)

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[token_buf]                                          # (E, C, d)

    if spec.expert_shard_axis:
        # pin the expert-parallel layout: E over the model axis.  Without
        # this GSPMD replicates xe/h in f32 across every device (§Perf).
        from jax.sharding import PartitionSpec as _P
        _pin = lambda a: jax.lax.with_sharding_constraint(
            a, _P(spec.expert_shard_axis, None, None))
        xe = _pin(xe)
    else:
        _pin = lambda a: a

    h = _pin(jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"]))
             * jnp.einsum("ecd,edf->ecf", xe, params["w_up"]))
    ye = _pin(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))    # (E, C, d)
    ye = ye * gate_buf[..., None].astype(ye.dtype)

    y = jnp.zeros((t + 1, d), ye.dtype).at[token_buf.reshape(-1)].add(
        ye.reshape(-1, d))[:t]

    if "shared" in params:
        sh = params["shared"]
        hs = jax.nn.silu(xf @ sh["w_gate"]) * (xf @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    return y, aux
