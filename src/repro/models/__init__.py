"""Model zoo: composable block programs (dense/GQA, MLA, MoE, SWA, Mamba2,
mLSTM/sLSTM, VLM cross-attention, multi-codebook audio)."""
from repro.models import attention, layers, moe, ssm, transformer, xlstm  # noqa: F401
from repro.models.transformer import (decode_step, forward, init_cache,  # noqa: F401
                                      init_params)
