"""Mamba2 (SSD) block: chunked selective-state-space computation.

Recurrence (per head h, state (N, P)):   H_t = a_t H_{t-1} + B_t (dt_t x_t)^T
Output:                                  y_t = C_t · H_t + D x_t

Training uses the chunked SSD algorithm (Dao & Gu, 2024): quadratic
attention-like form inside chunks of length L, a sequential ``lax.scan``
carry across the S/L chunks.  Decode is the O(1) single-step recurrence on a
cached state.  All tensors stay (B, S, H, ·) — no (B, S, H, N, P) per-token
states are ever materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMSpec
from repro.models.layers import causal_conv1d, causal_conv1d_init, dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


def _dims(cfg: ModelConfig, spec: SSMSpec):
    d_inner = spec.expand * cfg.d_model
    n_heads = d_inner // spec.head_dim
    return d_inner, n_heads


def init_mamba(key, cfg: ModelConfig, spec: SSMSpec, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, h = _dims(cfg, spec)
    g, n = spec.n_groups, spec.d_state
    conv_dim = d_inner + 2 * g * n
    ks = jax.random.split(key, 5)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * g * n + h, dtype=dtype),
        "conv": causal_conv1d_init(ks[1], conv_dim, spec.d_conv, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(d_inner, dtype),
        "out_proj": dense_init(ks[2], d_inner, d, dtype=dtype),
    }


def _split_proj(params, u: Array, cfg: ModelConfig, spec: SSMSpec):
    d_inner, h = _dims(cfg, spec)
    g, n = spec.n_groups, spec.d_state
    zxbcdt = u @ params["in_proj"]
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * g * n], axis=-1)
    return z, xbc, dt_raw


def _gates(params, xbc_conv: Array, dt_raw: Array, cfg, spec):
    d_inner, h = _dims(cfg, spec)
    g, n = spec.n_groups, spec.d_state
    p = spec.head_dim
    x, bc = jnp.split(xbc_conv, [d_inner], axis=-1)
    b_, c_ = jnp.split(bc, 2, axis=-1)
    bsz = x.shape[:-1]
    x = x.reshape(*bsz, h, p)
    b_ = b_.reshape(*bsz, g, n)
    c_ = c_.reshape(*bsz, g, n)
    rep = h // g
    b_ = jnp.repeat(b_, rep, axis=-2)                        # (.., H, N)
    c_ = jnp.repeat(c_, rep, axis=-2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = jnp.exp(params["a_log"])                             # (H,) > 0
    la = -dt * a                                             # log decay <= 0
    return x, b_, c_, dt, la


def mamba_prefill(params, u: Array, cfg: ModelConfig, spec: SSMSpec, *,
                  make_cache: bool = False):
    """u: (B, S, d_model) -> (y, cache | None)."""
    bsz, s, _ = u.shape
    d_inner, h = _dims(cfg, spec)
    p, n = spec.head_dim, spec.d_state
    z, xbc, dt_raw = _split_proj(params, u, cfg, spec)
    if make_cache:
        xbc_conv, conv_state = causal_conv1d(params["conv"], xbc,
                                             _zero_conv_state(params, bsz, xbc.dtype))
    else:
        xbc_conv = causal_conv1d(params["conv"], xbc)
        conv_state = None
    x, b_, c_, dt, la = _gates(params, xbc_conv, dt_raw, cfg, spec)

    y, final_state = _ssd_chunked(x, b_, c_, dt, la, spec.chunk)
    y = y + x.astype(jnp.float32) * params["d_skip"][:, None]   # D skip (H,1)
    y = y.reshape(bsz, s, d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ params["out_proj"]
    cache = None
    if make_cache:
        cache = {"ssm": final_state, "conv": conv_state}
    return out, cache


def mamba_decode(params, u: Array, cfg: ModelConfig, spec: SSMSpec, cache: dict):
    """u: (B, 1, d_model); cache: {'ssm': (B,H,N,P) f32, 'conv': (B,W-1,C)}."""
    bsz = u.shape[0]
    d_inner, h = _dims(cfg, spec)
    z, xbc, dt_raw = _split_proj(params, u, cfg, spec)
    xbc_conv, conv_state = causal_conv1d(params["conv"], xbc, cache["conv"])
    x, b_, c_, dt, la = _gates(params, xbc_conv, dt_raw, cfg, spec)
    # single step: squeeze S=1
    x1 = x[:, 0].astype(jnp.float32)            # (B,H,P)
    b1 = b_[:, 0].astype(jnp.float32)           # (B,H,N)
    c1 = c_[:, 0].astype(jnp.float32)
    dt1 = dt[:, 0]                              # (B,H)
    a1 = jnp.exp(la[:, 0])                      # (B,H)
    hst = cache["ssm"] * a1[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", b1, x1 * dt1[..., None])
    y1 = jnp.einsum("bhn,bhnp->bhp", c1, hst) + x1 * params["d_skip"][:, None]
    y = y1.reshape(bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ params["out_proj"], {"ssm": hst, "conv": conv_state}


def _zero_conv_state(params, bsz: int, dtype):
    w = params["conv"]["w"]
    return jnp.zeros((bsz, w.shape[0] - 1, w.shape[1]), dtype)


def init_mamba_cache(params, cfg: ModelConfig, spec: SSMSpec, bsz: int, dtype):
    d_inner, h = _dims(cfg, spec)
    return {
        "ssm": jnp.zeros((bsz, h, spec.d_state, spec.head_dim), jnp.float32),
        "conv": _zero_conv_state(params, bsz, dtype),
    }


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------


def _ssd_chunked(x: Array, b_: Array, c_: Array, dt: Array, la: Array,
                 chunk: int):
    """x: (B,S,H,P); b_/c_: (B,S,H,N); dt/la: (B,S,H).

    Returns y (B,S,H,P) float32 and final state (B,H,N,P) float32.
    """
    bsz, s0, h, p = x.shape
    n = b_.shape[-1]
    l = min(chunk, s0)
    pad = (-s0) % l
    if pad:
        # zero x/B/C contributions, zero log-decay (a=1) => state preserved
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, b_, c_, dt, la = zp(x), zp(b_), zp(c_), zp(dt), zp(la)
    s = s0 + pad
    nc = s // l

    xb = (x.astype(jnp.float32) * dt[..., None]).reshape(bsz, nc, l, h, p)
    bb = b_.astype(jnp.float32).reshape(bsz, nc, l, h, n)
    cb = c_.astype(jnp.float32).reshape(bsz, nc, l, h, n)
    lab = la.reshape(bsz, nc, l, h)

    cum = jnp.cumsum(lab, axis=2)                    # within-chunk cumulative
    total = cum[:, :, -1, :]                         # (B,NC,H)

    # intra-chunk quadratic form: w_ij = exp(cum_i - cum_j) for i >= j.
    # Mask INSIDE the exp: masked (i < j) entries have diff > 0 and would
    # overflow to inf, poisoning the backward pass through where().
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,NC,L,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))
    w = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -1e30))
    scores = jnp.einsum("bclhn,bcmhn->bclmh", cb, bb) * w
    y_intra = jnp.einsum("bclmh,bcmhp->bclhp", scores, xb)

    # chunk summary states: S_c = sum_j exp(total - cum_j) B_j x_j^T
    decay_tail = jnp.exp(total[:, :, None, :] - cum)          # (B,NC,L,H)
    st = jnp.einsum("bclh,bclhn,bclhp->bchnp", decay_tail, bb, xb)

    # sequential scan over chunks
    def scan_fn(hprev, inp):
        st_c, tot_c = inp                                     # (B,H,N,P), (B,H)
        hnew = hprev * jnp.exp(tot_c)[..., None, None] + st_c
        return hnew, hprev

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    hfinal, hprevs = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(st, 1, 0), jnp.moveaxis(total, 1, 0)))
    hprevs = jnp.moveaxis(hprevs, 0, 1)                       # (B,NC,H,N,P)

    # inter-chunk contribution: y_i += exp(cum_i) C_i · H_{c-1}
    y_inter = jnp.einsum("bclh,bclhn,bchnp->bclhp",
                         jnp.exp(cum), cb, hprevs)
    y = (y_intra + y_inter).reshape(bsz, s, h, p)[:, :s0]
    return y, hfinal


def ssd_reference(x, b_, c_, dt, la):
    """O(S) sequential oracle for tests: plain recurrence."""
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    xb = x.astype(jnp.float32) * dt[..., None]

    def step(hprev, inp):
        xt, bt, ct, lat = inp
        hnew = hprev * jnp.exp(lat)[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt)
        yt = jnp.einsum("bhn,bhnp->bhp", ct, hnew)
        return hnew, yt

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    hfinal, ys = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(xb, 1, 0), jnp.moveaxis(b_.astype(jnp.float32), 1, 0),
         jnp.moveaxis(c_.astype(jnp.float32), 1, 0), jnp.moveaxis(la, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), hfinal
