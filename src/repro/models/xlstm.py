"""xLSTM cells: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential), following Beck et al. 2024 (arXiv:2405.04517).

mLSTM recurrence per head (state C: (dk, dv), normalizer n: (dk,)):
    C_t = f_t C_{t-1} + i_t k_t v_t^T       n_t = f_t n_{t-1} + i_t k_t
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))
with exponential input gate i = exp(i_raw), forget gate f = sigmoid(f_raw),
stabilized in log space by the running max m_t (as in the paper's appendix).
Training uses a chunkwise form (like SSD) so no per-token (dk, dv) states
are materialized; decode is the O(1) recurrence.

sLSTM: per-unit scalar memory with block-diagonal (per-head) recurrent
weights, computed with a sequential ``lax.scan`` (inherently recurrent —
this is the paper's trade-off, and it shows up in the roofline as a long
scalar dependency chain rather than MXU work).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMSpec
from repro.models.layers import (causal_conv1d, causal_conv1d_init, dense_init,
                                 rmsnorm, rmsnorm_init)

Array = jax.Array


def _mdims(cfg: ModelConfig, spec: XLSTMSpec):
    d_inner = int(cfg.d_model * spec.proj_factor)
    h = cfg.n_heads
    dk = d_inner // h
    return d_inner, h, dk


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, spec: XLSTMSpec, dtype=jnp.float32):
    d = cfg.d_model
    d_inner, h, dk = _mdims(cfg, spec)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype=dtype),      # x, z paths
        "conv": causal_conv1d_init(ks[1], d_inner, spec.conv_window, dtype),
        "wq": dense_init(ks[2], d_inner, d_inner, dtype=dtype),
        "wk": dense_init(ks[3], d_inner, d_inner, dtype=dtype),
        "wv": dense_init(ks[4], d_inner, d_inner, dtype=dtype),
        "w_if": dense_init(ks[5], d_inner, 2 * h, scale=0.02, dtype=jnp.float32),
        "if_bias": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "norm": rmsnorm_init(d_inner, dtype),
        "w_down": dense_init(ks[6], d_inner, d, dtype=dtype),
    }


def _mlstm_qkvif(params, u: Array, cfg, spec, conv_state=None):
    d_inner, h, dk = _mdims(cfg, spec)
    xz = u @ params["w_up"]
    x_path, z = jnp.split(xz, 2, axis=-1)
    if conv_state is None:
        xc = causal_conv1d(params["conv"], x_path)
        new_conv = None
    else:
        xc, new_conv = causal_conv1d(params["conv"], x_path, conv_state)
    bsz, s, _ = u.shape
    q = (xc @ params["wq"]).reshape(bsz, s, h, dk)
    k = (xc @ params["wk"]).reshape(bsz, s, h, dk) * dk ** -0.5
    v = (x_path @ params["wv"]).reshape(bsz, s, h, dk)
    gif = xc.astype(jnp.float32) @ params["w_if"] + params["if_bias"]
    li = gif[..., :h]                                   # log input gate (exp gate)
    lf = jax.nn.log_sigmoid(gif[..., h:])               # log forget gate
    return q, k, v, li, lf, z, new_conv


def mlstm_prefill(params, u: Array, cfg: ModelConfig, spec: XLSTMSpec, *,
                  make_cache: bool = False):
    bsz, s, _ = u.shape
    d_inner, h, dk = _mdims(cfg, spec)
    conv0 = _zero_conv(params, bsz, u.dtype) if make_cache else None
    q, k, v, li, lf, z, new_conv = _mlstm_qkvif(params, u, cfg, spec, conv0)
    y, state = _mlstm_chunked(q, k, v, li, lf, spec.chunk)
    y = y.reshape(bsz, s, d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["w_down"]
    cache = None
    if make_cache:
        cache = {"C": state[0], "n": state[1], "m": state[2], "conv": new_conv}
    return out, cache


def mlstm_decode(params, u: Array, cfg: ModelConfig, spec: XLSTMSpec, cache: dict):
    bsz = u.shape[0]
    d_inner, h, dk = _mdims(cfg, spec)
    q, k, v, li, lf, z, new_conv = _mlstm_qkvif(params, u, cfg, spec, cache["conv"])
    q1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
    li1, lf1 = li[:, 0], lf[:, 0]                        # (B,H)
    m_prev, c_prev, n_prev = cache["m"], cache["C"], cache["n"]
    m_new = jnp.maximum(lf1 + m_prev, li1)
    c_new = (c_prev * jnp.exp(lf1 + m_prev - m_new)[..., None, None]
             + jnp.exp(li1 - m_new)[..., None, None]
             * jnp.einsum("bhk,bhv->bhkv", k1, v1))
    n_new = (n_prev * jnp.exp(lf1 + m_prev - m_new)[..., None]
             + jnp.exp(li1 - m_new)[..., None] * k1)
    num = jnp.einsum("bhk,bhkv->bhv", q1, c_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q1, n_new)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return y @ params["w_down"], {"C": c_new, "n": n_new, "m": m_new,
                                  "conv": new_conv}


def init_mlstm_cache(params, cfg: ModelConfig, spec: XLSTMSpec, bsz: int, dtype):
    d_inner, h, dk = _mdims(cfg, spec)
    return {
        "C": jnp.zeros((bsz, h, dk, dk), jnp.float32),
        "n": jnp.zeros((bsz, h, dk), jnp.float32),
        "m": jnp.full((bsz, h), -1e30, jnp.float32),
        "conv": _zero_conv(params, bsz, dtype),
    }


def _zero_conv(params, bsz, dtype):
    w = params["conv"]["w"]
    return jnp.zeros((bsz, w.shape[0] - 1, w.shape[1]), dtype)


def _mlstm_chunked(q, k, v, li, lf, chunk: int):
    """Chunkwise stabilized mLSTM.  q/k/v: (B,S,H,D); li/lf: (B,S,H) f32.

    Returns y (B,S,H,D) f32 and final (C, n, m) state.
    """
    bsz, s0, h, dk = q.shape
    l = min(chunk, s0)
    pad = (-s0) % l
    if pad:
        zp = lambda a, v=0.0: jnp.pad(
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2), constant_values=v)
        # li = -inf (no input), lf = 0 (decay 1) => carry state preserved
        q, k, v = zp(q), zp(k), zp(v)
        li, lf = zp(li, -1e30), zp(lf, 0.0)
    s = s0 + pad
    nc = s // l
    rs = lambda a: a.reshape(bsz, nc, l, *a.shape[2:])
    qc, kc, vc = (rs(a.astype(jnp.float32)) for a in (q, k, v))
    lic, lfc = rs(li), rs(lf)

    cumf = jnp.cumsum(lfc, axis=2)                     # within-chunk sum of lf
    total = cumf[:, :, -1, :]                          # (B,NC,H)
    # per-entry source weight (log): b_j = li_j - cumf_j  (for carry into i)
    src = lic - cumf                                   # (B,NC,L,H)
    mask = jnp.tril(jnp.ones((l, l), bool))

    # stabilizers: running max within chunk of src, combined with carry m
    run_src = jax.lax.associative_scan(jnp.maximum, src, axis=2)  # (B,NC,L,H)

    def scan_fn(carry, inp):
        c_prev, n_prev, m_prev = carry                 # (B,H,dk,dk),(B,H,dk),(B,H)
        qb, kb, vb, cumf_b, total_b, src_b, run_src_b, lic_b = inp
        # intra-chunk pair weight (log): cumf_i - cumf_j + li_j  (i >= j)
        pair_b = (cumf_b[:, :, None, :] - cumf_b[:, None, :, :]
                  + lic_b[:, None, :, :])              # (B,L,L,H)
        # per-position stabilizer: m_i = max(m_prev + cumf_i, cumf_i + runmax src)
        m_loc = cumf_b + run_src_b                     # (B,L,H)
        m_i = jnp.maximum(m_prev[:, None, :] + cumf_b, m_loc)
        # inter-chunk: y_i += exp(cumf_i + m_prev - m_i) q_i . C_prev
        w_carry = jnp.exp(cumf_b + m_prev[:, None, :] - m_i)     # (B,L,H)
        y_inter = jnp.einsum("blhk,bhkv->blhv", qb, c_prev) * w_carry[..., None]
        n_inter = jnp.einsum("blhk,bhk->blh", qb, n_prev) * w_carry

        # intra-chunk: w_ij = exp(pair_ij - m_i); mask INSIDE the exp so the
        # backward pass never sees inf * 0 (masked i<j entries can overflow)
        wij = jnp.exp(jnp.where(mask[None, :, :, None],
                                pair_b - m_i[:, :, None, :], -1e30))
        scores = jnp.einsum("blhk,bmhk->blmh", qb, kb) * wij
        y_intra = jnp.einsum("blmh,bmhv->blhv", scores, vb)
        n_intra = jnp.einsum("blmh,bmhk->blhk", wij, kb)
        n_intra_q = jnp.einsum("blhk,blhk->blh", qb, n_intra)

        num = y_inter + y_intra
        den = jnp.maximum(jnp.abs(n_inter + n_intra_q), jnp.exp(-m_i))
        yb = num / den[..., None]

        # carry update to end of chunk with new stabilizer
        m_new = jnp.maximum(m_prev + total_b, total_b + run_src_b[:, -1, :])
        wc = jnp.exp(m_prev + total_b - m_new)                    # (B,H)
        ws = jnp.exp(total_b[:, None, :] + src_b - m_new[:, None, :])  # (B,L,H)
        c_new = (c_prev * wc[..., None, None]
                 + jnp.einsum("blh,blhk,blhv->bhkv", ws, kb, vb))
        n_new = n_prev * wc[..., None] + jnp.einsum("blh,blhk->bhk", ws, kb)
        return (c_new, n_new, m_new), yb

    c0 = jnp.zeros((bsz, h, dk, dk), jnp.float32)
    n0 = jnp.zeros((bsz, h, dk), jnp.float32)
    m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    mv = lambda a: jnp.moveaxis(a, 1, 0)
    (cf, nf, mf), ys = jax.lax.scan(
        scan_fn, (c0, n0, m0),
        (mv(qc), mv(kc), mv(vc), mv(cumf), mv(total), mv(src), mv(run_src),
         mv(lic)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, dk)[:, :s0]
    return y, (cf, nf, mf)


def mlstm_reference(q, k, v, li, lf):
    """Sequential oracle for tests."""
    bsz, s, h, dk = q.shape

    def step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qt, kt, vt, lit, lft = inp
        m_new = jnp.maximum(lft + m_prev, lit)
        c_new = (c_prev * jnp.exp(lft + m_prev - m_new)[..., None, None]
                 + jnp.exp(lit - m_new)[..., None, None]
                 * jnp.einsum("bhk,bhv->bhkv", kt, vt))
        n_new = (n_prev * jnp.exp(lft + m_prev - m_new)[..., None]
                 + jnp.exp(lit - m_new)[..., None] * kt)
        num = jnp.einsum("bhk,bhkv->bhv", qt, c_new)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qt, n_new)),
                          jnp.exp(-m_new))
        return (c_new, n_new, m_new), num / den[..., None]

    c0 = jnp.zeros((bsz, h, dk, dk), jnp.float32)
    n0 = jnp.zeros((bsz, h, dk), jnp.float32)
    m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    mv = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    (cf, nf, mf), ys = jax.lax.scan(
        step, (c0, n0, m0), (mv(q), mv(k), mv(v), mv(li), mv(lf)))
    return jnp.moveaxis(ys, 0, 1), (cf, nf, mf)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, spec: XLSTMSpec, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    d_ff = int(d * spec.slstm_proj_factor)
    return {
        # input weights for gates (i, f, z, o)
        "w_in": dense_init(ks[0], d, 4 * d, dtype=dtype),
        # block-diagonal recurrent weights per head: (H, dh, 4*dh)
        "r": (jax.random.normal(ks[1], (h, dh, 4 * dh)) * dh ** -0.5
              ).astype(dtype),
        "bias": jnp.concatenate(
            [jnp.zeros((d,)), 3.0 * jnp.ones((d,)), jnp.zeros((2 * d,))]),
        "norm": rmsnorm_init(d, dtype),
        # position-wise gated FFN after the cell
        "w_ff_gate": dense_init(ks[2], d, d_ff, dtype=dtype),
        "w_ff_down": dense_init(ks[3], d_ff, d, dtype=dtype),
    }


def _slstm_step(params, carry, wx_t, cfg: ModelConfig):
    """One recurrence step.  carry: (c, n, h, m) each (B, d)."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    c_prev, n_prev, h_prev, m_prev = carry
    bsz = c_prev.shape[0]
    hh = h_prev.reshape(bsz, nh, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, params["r"]).reshape(bsz, 4 * d)
    g = wx_t + rec + params["bias"]
    gi, gf, gz, go = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m_prev, gi)
    i_ = jnp.exp(gi - m_new)
    f_ = jnp.exp(lf + m_prev - m_new)
    z_ = jnp.tanh(gz)
    o_ = jax.nn.sigmoid(go)
    c_new = f_ * c_prev + i_ * z_
    n_new = jnp.maximum(f_ * n_prev + i_, jnp.exp(-m_new))
    h_new = o_ * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_prefill(params, u: Array, cfg: ModelConfig, spec: XLSTMSpec, *,
                  make_cache: bool = False):
    bsz, s, d = u.shape
    wx = u @ params["w_in"]                                  # (B,S,4d)

    def step(carry, wx_t):
        new = _slstm_step(params, carry, wx_t, cfg)
        return new, new[2]

    carry0 = init_slstm_cache(params, cfg, spec, bsz, u.dtype)["state"]
    carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(u.dtype)               # (B,S,d)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = (jax.nn.silu(y @ params["w_ff_gate"])) @ params["w_ff_down"]
    return y, ({"state": carry} if make_cache else None)


def slstm_decode(params, u: Array, cfg: ModelConfig, spec: XLSTMSpec, cache: dict):
    wx = (u @ params["w_in"])[:, 0]
    new = _slstm_step(params, cache["state"], wx, cfg)
    y = new[2][:, None].astype(u.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = (jax.nn.silu(y @ params["w_ff_gate"])) @ params["w_ff_down"]
    return y, {"state": new}


def init_slstm_cache(params, cfg: ModelConfig, spec: XLSTMSpec, bsz: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((bsz, d), jnp.float32)
    return {"state": (z, jnp.ones_like(z), z, jnp.full((bsz, d), -1e30))}
