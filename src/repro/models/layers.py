"""Shared neural building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, scale: float | None = None,
               dtype=jnp.float32) -> Array:
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)


def orthogonal_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> Array:
    """Orthonormal-column init — Stiefel-feasible starting point for
    manifold-constrained weights (the paper initializes on St(d, r))."""
    tall = d_in >= d_out
    a = jax.random.normal(key, (d_in, d_out) if tall else (d_out, d_in))
    q, _ = jnp.linalg.qr(a)
    return (q if tall else q.T).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms / activations
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x: Array, eps: float = 1e-5) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return out.astype(x.dtype) * params["scale"]


def swiglu_init(key, d: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype=dtype),
        "w_up": dense_init(k2, d, d_ff, dtype=dtype),
        "w_down": dense_init(k3, d_ff, d, dtype=dtype),
    }


def swiglu(params, x: Array) -> Array:
    g = jax.nn.silu(x @ params["w_gate"])
    return (g * (x @ params["w_up"])) @ params["w_down"]


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                                 # (..., S, 1, hd/2)
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# depthwise causal conv (mamba / mLSTM front conv)
# ---------------------------------------------------------------------------


def causal_conv1d_init(key, channels: int, width: int, dtype=jnp.float32):
    return {"w": (jax.random.normal(key, (width, channels)) * (1.0 / width) ** 0.5
                  ).astype(dtype)}


def causal_conv1d(params, x: Array, state: Array | None = None):
    """x: (B, S, C) depthwise causal conv.  If ``state`` (B, W-1, C) is given,
    runs in streaming mode and returns (y, new_state)."""
    w = params["w"]                        # (W, C)
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((*x.shape[:-2], width - 1, x.shape[-1]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=-2)
        y = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(width))
        return jax.nn.silu(y)
    xp = jnp.concatenate([state, x], axis=-2)        # (B, W-1+S, C)
    y = sum(xp[..., i:i + x.shape[-2], :] * w[i] for i in range(width))
    new_state = xp[..., -(width - 1):, :]
    return jax.nn.silu(y), new_state
