"""Deterministic synthetic data streams (offline container — no downloads).

Two generators, both node-sharded and *heterogeneous across nodes* (each
node over-samples a different group mixture, which is exactly the regime
where decentralized DRO/minimax training is non-trivial and consensus
matters):

* :class:`ClassificationStream` — Gaussian-cluster images, ``n_classes``
  classes; stands in for MNIST/F-MNIST/CIFAR in the paper's fair
  classification and DRO experiments (same shapes and group structure).
* :class:`TokenStream` — group-conditioned unigram/bigram token streams for
  the LM architectures; each group g has a distinct Zipf-ish distribution
  over a vocabulary slice, so per-group losses genuinely differ and the
  minimax weights move.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _node_group_mixture(n_nodes: int, n_groups: int, hetero: float,
                        rng: np.random.Generator) -> np.ndarray:
    """Row-stochastic (n_nodes, n_groups): node i's sampling mixture."""
    base = np.full((n_nodes, n_groups), 1.0 / n_groups)
    pref = rng.dirichlet(np.full(n_groups, 0.3), size=n_nodes)
    return (1.0 - hetero) * base + hetero * pref


@dataclasses.dataclass
class ClassificationStream:
    n_nodes: int
    batch_per_node: int
    image_hw: int = 14
    channels: int = 1
    n_classes: int = 3
    hetero: float = 0.7
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        d = self.image_hw * self.image_hw * self.channels
        self.means = rng.normal(size=(self.n_classes, d)).astype(np.float32)
        self.mix = _node_group_mixture(self.n_nodes, self.n_classes,
                                       self.hetero, rng)

    @property
    def input_dim(self) -> int:
        return self.image_hw * self.image_hw * self.channels

    def batch(self, step: int) -> dict:
        """Node-stacked {images (N,B,H,W,C), labels (N,B)} — deterministic."""
        rng = np.random.default_rng((self.seed, 1, step))
        n, b = self.n_nodes, self.batch_per_node
        labels = np.stack([
            rng.choice(self.n_classes, size=b, p=self.mix[i])
            for i in range(n)])
        eps = rng.normal(size=(n, b, self.input_dim)).astype(np.float32)
        x = self.means[labels] + self.noise * eps
        x = x.reshape(n, b, self.image_hw, self.image_hw, self.channels)
        return {"images": x, "labels": labels.astype(np.int32)}

    def full(self, n_batches: int = 4) -> dict:
        """A fixed 'full local dataset' for the deterministic methods."""
        bs = [self.batch(s) for s in range(n_batches)]
        return {k: np.concatenate([b[k] for b in bs], axis=1) for k in bs[0]}


@dataclasses.dataclass
class TokenStream:
    n_nodes: int
    batch_per_node: int
    seq_len: int
    vocab_size: int
    n_groups: int = 8
    n_codebooks: int = 1
    hetero: float = 0.7
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.mix = _node_group_mixture(self.n_nodes, self.n_groups,
                                       self.hetero, rng)
        # group g prefers a slice of the vocabulary (Zipf within slice)
        v = self.vocab_size
        self.group_probs = np.zeros((self.n_groups, v), np.float64)
        ranks = 1.0 / np.arange(1, v + 1)
        for g in range(self.n_groups):
            perm = np.random.default_rng((self.seed, 2, g)).permutation(v)
            self.group_probs[g, perm] = ranks
            self.group_probs[g] /= self.group_probs[g].sum()

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, 3, step))
        n, b, s = self.n_nodes, self.batch_per_node, self.seq_len
        gids = np.stack([
            rng.choice(self.n_groups, size=b, p=self.mix[i])
            for i in range(n)])
        shape = (n, b, s) if self.n_codebooks == 1 else \
            (n, b, s, self.n_codebooks)
        toks = np.empty(shape, np.int32)
        for i in range(n):
            for j in range(b):
                p = self.group_probs[gids[i, j]]
                toks[i, j] = rng.choice(self.vocab_size, size=shape[2:], p=p)
        return {"tokens": toks, "group_ids": gids.astype(np.int32)}
