from repro.data.synthetic import ClassificationStream, TokenStream  # noqa: F401
