"""Problem interface for decentralized Riemannian minimax optimization.

A :class:`MinimaxProblem` packages everything the optimizers in
:mod:`repro.core.gda` / :mod:`repro.core.baselines` need:

  * ``loss_fn(x, y, batch) -> scalar``   — the *local* objective f_i of one
    node (min over ``x``, max over ``y``);
  * ``project_y``                        — Euclidean projection onto the
    compact convex set ``Y`` (simplex, ball, box, ...);
  * ``manifold_map``                     — pytree (same structure as ``x``)
    describing which geometry each leaf lives on: a
    :class:`repro.geometry.Manifold` instance, a registry name string, or a
    legacy bool (True -> Stiefel, False -> Euclidean).  The legacy
    ``stiefel_mask`` argument still works and feeds the same map;
  * optionally ``y_star(x, batch)``      — the exact inner maximizer, used by
    the convergence metric M_t (Eq. 16). Available in closed form for the
    paper's quadratic-in-y objectives (Eqs. 20, 21).

The node dimension is *not* part of this interface: optimizers vmap the
problem over the leading node axis themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro import geometry
from repro.geometry import Product

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# projections onto common Y sets
# ---------------------------------------------------------------------------


def project_simplex(y: Array) -> Array:
    """Euclidean projection onto the probability simplex (last axis).

    Standard sort-based algorithm (Held et al.); O(k log k), jit-safe.
    """
    k = y.shape[-1]
    u = jnp.sort(y, axis=-1)[..., ::-1]
    css = jnp.cumsum(u, axis=-1) - 1.0
    idx = jnp.arange(1, k + 1, dtype=y.dtype)
    cond = u - css / idx > 0
    rho = jnp.sum(cond, axis=-1, keepdims=True)  # >= 1 always
    theta = jnp.take_along_axis(css, rho - 1, axis=-1) / rho.astype(y.dtype)
    return jnp.maximum(y - theta, 0.0)


def project_l2_ball(radius: float) -> Callable[[Array], Array]:
    def proj(y: Array) -> Array:
        nrm = jnp.linalg.norm(y, axis=-1, keepdims=True)
        scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-12))
        return y * scale
    return proj


def project_box(lo: float, hi: float) -> Callable[[Array], Array]:
    return lambda y: jnp.clip(y, lo, hi)


# ---------------------------------------------------------------------------
# the problem container
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MinimaxProblem:
    """min_{x in M} max_{y in Y} f(x, y; data) — one node's local view.

    ``stiefel_mask`` (legacy bool pytree) and ``manifold_map`` (geometry
    spec pytree) are interchangeable inputs; after construction,
    ``manifold_map`` always holds normalized Manifold instances and
    ``stiefel_mask`` the derived bool view (True on Stiefel leaves).
    """

    loss_fn: Callable[[PyTree, Array, Any], Array]
    project_y: Callable[[Array], Array]
    stiefel_mask: PyTree = None
    y_star: Optional[Callable[[PyTree, Any], Array]] = None
    # aux outputs (per-group losses etc.) for logging; loss_fn_aux returns
    # (loss, aux) when provided.
    loss_fn_aux: Optional[Callable[[PyTree, Array, Any], tuple]] = None
    name: str = "problem"
    manifold_map: PyTree = None

    def __post_init__(self):
        spec = self.manifold_map if self.manifold_map is not None \
            else self.stiefel_mask
        if spec is None:
            raise ValueError(
                "MinimaxProblem needs a manifold_map (or legacy stiefel_mask)")
        mmap = geometry.as_manifold_map(spec)
        object.__setattr__(self, "manifold_map", mmap)
        object.__setattr__(self, "stiefel_mask", geometry.bool_mask(mmap))

    @property
    def manifold(self) -> Product:
        """The product geometry over the whole parameter pytree."""
        return Product(self.manifold_map)

    # -- gradients ---------------------------------------------------------
    def grads(self, x: PyTree, y: Array, batch: Any) -> tuple[PyTree, Array]:
        """(euclidean grad_x, grad_y) of the local loss at (x, y)."""
        gx, gy = jax.grad(self.loss_fn, argnums=(0, 1))(x, y, batch)
        return gx, gy

    def rgrads(self, x: PyTree, y: Array, batch: Any) -> tuple[PyTree, Array]:
        """(Riemannian grad_x, euclidean grad_y).

        Constrained leaves are tangent-projected at their own base point
        (this is the ``grad_x f_i`` in Alg. 1 steps 2/6); Euclidean leaves
        pass through.
        """
        gx, gy = self.grads(x, y, batch)
        rgx = jax.tree.map(lambda m, xi, gi: m.tangent_project(xi, gi),
                           self.manifold_map, x, gx)
        return rgx, gy

    def value(self, x: PyTree, y: Array, batch: Any) -> Array:
        return self.loss_fn(x, y, batch)


def apply_masked(mask: PyTree, x: PyTree, g: PyTree, *, stiefel_fn, eucl_fn):
    """tree_map dispatching on a per-leaf bool Stiefel mask (legacy helper —
    new code should tree-map over a manifold_map instead)."""
    return jax.tree.map(
        lambda m, xi, gi: stiefel_fn(xi, gi) if m else eucl_fn(xi, gi),
        mask, x, g,
    )


def stiefel_mask_from_paths(params: PyTree, predicate: Callable[[str], bool]) -> PyTree:
    """Build a bool mask pytree by matching flattened key-paths.

    ``predicate`` receives a '/'-joined path string such as
    ``'layers_0/attn/wq'``.  See
    :func:`repro.geometry.manifold_map_from_paths` for the geometry-generic
    version this wraps.
    """
    mmap = geometry.manifold_map_from_paths(params, predicate,
                                            manifold="stiefel")
    return geometry.bool_mask(mmap)


def validate_manifold(params: PyTree, map_or_mask: PyTree) -> Array:
    """Max feasibility residual over all constrained leaves (0.0 if none)."""
    mmap = geometry.as_manifold_map(map_or_mask)
    errs = [jnp.max(m.check(x))
            for m, x in zip(jax.tree.leaves(mmap), jax.tree.leaves(params))
            if m.name != "euclidean"]
    if not errs:
        return jnp.zeros(())
    return jnp.max(jnp.stack(errs))


def validate_stiefel(params: PyTree, mask: PyTree, atol: float = 1e-4) -> Array:
    """Legacy alias of :func:`validate_manifold` (bool-mask call sites)."""
    return validate_manifold(params, mask)
