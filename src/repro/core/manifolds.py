"""Stiefel-manifold primitives — back-compat facade over ``repro.geometry``.

The math lives in :mod:`repro.geometry.stiefel` (one geometry of the
pluggable manifold registry); this module keeps the historical flat-function
surface (``tangent_project``/``retract_polar``/``project_stiefel``/...)
that the paper-era call sites and tests use.  ``retract`` dispatches through
the registered Stiefel geometry, so new retraction kinds (``cayley``,
``polar_fused``) are available here without another if/elif ladder.
"""
from __future__ import annotations

from repro.geometry import STIEFEL
from repro.geometry.stiefel import (  # noqa: F401
    consensus_error,
    induced_arithmetic_mean,
    invsqrt_spd,
    is_tangent,
    project_stiefel,
    random_stiefel,
    retract_cayley,
    retract_polar,
    retract_qr,
    rgd_step,
    riemannian_grad,
    stiefel_error,
    sym,
    tangent_project,
)
from repro.geometry.stiefel import _invsqrt_eigh, _invsqrt_newton_schulz  # noqa: F401

import jax

Array = jax.Array


def retract(x: Array, u: Array, kind: str = "polar", **kw) -> Array:
    """R_x(u) — dispatched through the geometry registry's Stiefel entry
    (kinds: polar | qr | cayley | polar_fused)."""
    return STIEFEL.retract(x, u, kind, **kw)
