"""Gossip / consensus substrate.

Two execution paths for the mixing step ``x_i <- sum_j W_ij x_j``:

* ``mix_dense``  — arbitrary doubly-stochastic ``W`` via einsum over the
  (possibly sharded) leading node axis.  XLA lowers this to an all-gather on
  the node axis + local contraction.  Used for torus / expander topologies
  and in tests.
* ``mix_ring``   — the paper's experimental topology (ring, n=20): one hop
  touches only the two neighbours, expressed with ``jnp.roll`` along the node
  axis, which XLA lowers to ``collective-permute`` on the TPU ICI ring.  This
  is the TPU-native analogue of neighbour message passing and the default in
  production configs.

``W^k`` (the paper's multi-step gossip, Theorems 1/2 require
k >= ceil(log_{lambda_2}(1/(2 sqrt n)))) is ``k`` repeated one-hop mixes.

All mixing functions operate on pytrees whose leaves carry the node axis as
axis 0.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Literal, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # structural types only — no runtime comms import
    from repro.comms.api import CommLike, ElasticLike, MixBackendProtocol

Array = jax.Array
Topology = Literal["ring", "full", "torus", "star"]


# ---------------------------------------------------------------------------
# mixing matrices (numpy — built once at config time, static thereafter)
# ---------------------------------------------------------------------------


def ring_matrix(n: int, self_weight: float | None = None) -> np.ndarray:
    """Symmetric doubly-stochastic ring: each node averages itself and its two
    neighbours.  Default Metropolis weights => 1/3 each (n >= 3)."""
    if n == 1:
        return np.ones((1, 1))
    if n == 2:
        # degenerate ring: one neighbour, both "sides" are the same node
        wc = 0.5 if self_weight is None else self_weight
        return np.array([[wc, 1.0 - wc], [1.0 - wc, wc]])
    w_side = (1.0 - (self_weight if self_weight is not None else 1.0 / 3.0)) / 2.0
    wc = self_weight if self_weight is not None else 1.0 / 3.0
    w = np.zeros((n, n))
    for i in range(n):
        w[i, i] = wc
        w[i, (i - 1) % n] = w_side
        w[i, (i + 1) % n] = w_side
    return w


def full_matrix(n: int) -> np.ndarray:
    return np.full((n, n), 1.0 / n)


def torus_matrix(rows: int, cols: int) -> np.ndarray:
    """2-D torus, Metropolis weights (degree 4)."""
    n = rows * cols
    w = np.zeros((n, n))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            nbrs = [((r - 1) % rows) * cols + c, ((r + 1) % rows) * cols + c,
                    r * cols + (c - 1) % cols, r * cols + (c + 1) % cols]
            for j in set(nbrs) - {i}:
                w[i, j] = 1.0 / 5.0
            w[i, i] = 1.0 - w[i].sum()
    return w


def star_matrix(n: int) -> np.ndarray:
    """Star (centralized-like, for ablation): hub 0 <-> spokes."""
    w = np.zeros((n, n))
    for i in range(1, n):
        w[0, i] = w[i, 0] = 1.0 / n
        w[i, i] = 1.0 - 1.0 / n
    w[0, 0] = 1.0 - (n - 1) / n
    return w


def mixing_matrix(topology: Topology, n: int) -> np.ndarray:
    if topology == "ring":
        return ring_matrix(n)
    if topology == "full":
        return full_matrix(n)
    if topology == "star":
        return star_matrix(n)
    if topology == "torus":
        rows = int(math.sqrt(n))
        while n % rows:
            rows -= 1
        return torus_matrix(rows, n // rows)
    raise ValueError(f"unknown topology {topology!r}")


def second_largest_eigenvalue(w: np.ndarray) -> float:
    """lambda := second-largest |eigenvalue| of W (spectral gap driver)."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(w)))[::-1]
    return float(ev[1]) if len(ev) > 1 else 0.0


def required_gossip_steps(w: np.ndarray, n: int | None = None) -> int:
    """Paper's Theorem-1 prescription: k >= ceil( log_{lambda2} (1/(2 sqrt n)) ).

    lambda2 < 1, so log base lambda2 flips the inequality; equivalently
    k >= ln(2 sqrt n) / ln(1/lambda2).
    """
    n = n or w.shape[0]
    lam = second_largest_eigenvalue(w)
    if lam <= 0.0:
        return 1
    return max(1, int(math.ceil(math.log(2.0 * math.sqrt(n)) / math.log(1.0 / lam))))


# ---------------------------------------------------------------------------
# runtime mixing ops (jax)
# ---------------------------------------------------------------------------


def _mix_leaf_dense(w: Array, x: Array) -> Array:
    return jnp.einsum("ij,j...->i...", w, x)


def mix_dense(w: Array, tree, steps: int = 1):
    """x <- W^steps x, arbitrary W, leading node axis on every leaf."""
    def leaf(x):
        def body(_, v):
            return _mix_leaf_dense(w, v)
        return jax.lax.fori_loop(0, steps, body, x) if steps > 1 else _mix_leaf_dense(w, x)
    return jax.tree.map(leaf, tree)


def _mix_leaf_ring(x: Array, wc: float, ws: float) -> Array:
    # jnp.roll over the (sharded) node axis -> collective-permute on ICI.
    # The association wc*x + ws*(left + right) matches the ring_mix kernel
    # (and its jnp oracle) bit-for-bit, so every backend's per-row combine
    # is the same fp expression.
    return wc * x + ws * (jnp.roll(x, 1, axis=0) + jnp.roll(x, -1, axis=0))


def mix_ring(tree, steps: int = 1, self_weight: float = 1.0 / 3.0):
    """Ring gossip, ``steps`` hops.  Matches ring_matrix(n, self_weight)."""
    ws = (1.0 - self_weight) / 2.0

    def leaf(x):
        if x.shape[0] == 1:
            return x
        if x.shape[0] == 2:  # degenerate ring: full side weight to the peer
            def body2(_, v):
                return self_weight * v + (1.0 - self_weight) * jnp.roll(v, 1, axis=0)
            return jax.lax.fori_loop(0, steps, body2, x)
        def body(_, v):
            return _mix_leaf_ring(v, self_weight, ws)
        return jax.lax.fori_loop(0, steps, body, x)
    return jax.tree.map(leaf, tree)


@dataclasses.dataclass(frozen=True)
class GossipSpec:
    """Static description of the communication graph, carried by configs."""
    topology: Topology = "ring"
    n_nodes: int = 16
    k_steps: int | None = None      # None => Theorem-1 prescription
    self_weight: float = 1.0 / 3.0
    # Optional repro.comms.CommSpec — typed against the import-light
    # repro.comms.api.CommLike Protocol, so core type-checks the surface
    # without importing comms machinery at runtime.  When set and enabled,
    # the optimizers route mixing through repro.comms.layer.CommEngine
    # instead of the exact paths below.
    comm: Optional["CommLike"] = None
    # Optional mix backend (repro.comms.api.MixBackendProtocol) or a
    # registry name ("stacked" | "shard_map") resolved by resolve_backend.
    # None => the stacked reference backend; launch/steps.py plugs in a
    # ShardMapBackend when the training mesh has a real node axis.
    backend: Union["MixBackendProtocol", str, None] = None
    # Optional repro.comms.elastic.ElasticSpec (api.ElasticLike).  When set
    # and enabled, mixing runs in the elastic execution mode: membership
    # churn, stale-hop tolerance, realized W_t over the live subgraph.
    elastic: Optional["ElasticLike"] = None

    @property
    def matrix(self) -> np.ndarray:
        if self.topology == "ring":
            return ring_matrix(self.n_nodes, self.self_weight)
        return mixing_matrix(self.topology, self.n_nodes)

    @property
    def lam2(self) -> float:
        return second_largest_eigenvalue(self.matrix)

    @property
    def k(self) -> int:
        if self.k_steps is not None:
            return self.k_steps
        return required_gossip_steps(self.matrix, self.n_nodes)

    def mix(self, tree, steps: int | None = None):
        """Apply W^steps (default: the spec's k) to a node-stacked pytree.

        Execution is delegated to the spec's mix backend (see
        :mod:`repro.comms.backend`): the stacked roll/einsum paths when
        ``backend`` is None, neighbour-shard ``ppermute`` exchange under a
        ``ShardMapBackend``.  The topology matrices above stay the
        spectral-gap oracle either way.
        """
        s = self.k if steps is None else steps
        from repro.comms.backend import resolve_backend  # lazy: no cycle
        return resolve_backend(self).mix(self, tree, s)

    def mix_once(self, tree):
        return self.mix(tree, steps=1)
