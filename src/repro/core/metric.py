"""Convergence metric M_t (Eq. 16) and consensus diagnostics.

  M_t = || grad_x F(x_hat_t, y_bar_t) ||
      + (1/n) || x_t - x_hat_t ||
      + (L/n) || y_bar_t - y*(x_hat_t) ||

where x_hat is the IAM (Eq. 9) of the node replicas (Stiefel leaves) /
Euclidean mean (other leaves), y_bar the Euclidean mean, and y* the exact
inner maximizer (closed-form for the paper's quadratic-in-y objectives).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core import manifolds
from repro.core.minimax import MinimaxProblem, apply_masked

Array = jax.Array
PyTree = Any


def consensus_point(problem: MinimaxProblem, x_stacked: PyTree,
                    method: str = "eigh") -> PyTree:
    """x_hat: IAM for Stiefel leaves, arithmetic mean for Euclidean leaves."""
    return jax.tree.map(
        lambda m, xs: manifolds.induced_arithmetic_mean(xs, method)
        if m else jnp.mean(xs, axis=0),
        problem.stiefel_mask, x_stacked)


def global_riemannian_grad(problem: MinimaxProblem, x_hat: PyTree,
                           y_bar: Array, batches: Any) -> PyTree:
    """grad_x F(x_hat, y_bar) = (1/n) sum_i grad_x f_i — Riemannian.

    ``batches`` is node-stacked local data; params are broadcast.
    """
    n = jax.tree.leaves(batches)[0].shape[0]

    def one(bi):
        gx, _ = problem.grads(x_hat, y_bar, bi)
        return gx

    gx_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), jax.vmap(one)(batches))
    return apply_masked(problem.stiefel_mask, x_hat, gx_mean,
                        stiefel_fn=manifolds.tangent_project,
                        eucl_fn=lambda _, g: g)


def convergence_metric(problem: MinimaxProblem, x_stacked: PyTree,
                       y_stacked: Array, batches: Any, L: float = 1.0,
                       method: str = "eigh") -> dict[str, Array]:
    """Full M_t (Eq. 16) + components.  Deliberately not fused into the
    training step — it needs an extra global grad pass; benchmarks call it
    every ``eval_every`` steps."""
    n = y_stacked.shape[0]
    x_hat = consensus_point(problem, x_stacked, method)
    y_bar = jnp.mean(y_stacked, axis=0)

    g = global_riemannian_grad(problem, x_hat, y_bar, batches)
    grad_norm = jnp.sqrt(sum(jnp.sum(l ** 2) for l in jax.tree.leaves(g)))

    cons_x = jnp.sqrt(sum(
        jnp.sum((xs - xh[None]) ** 2)
        for xs, xh in zip(jax.tree.leaves(x_stacked), jax.tree.leaves(x_hat))))

    if problem.y_star is not None:
        # exact maximizer of the *global* objective at x_hat: average the
        # closed-form per-node maximizers' defining statistics by evaluating
        # y_star on the stacked batch with broadcast params.
        y_opt = problem.y_star(x_hat, batches)
        dist_y = jnp.linalg.norm(y_bar - y_opt)
    else:
        dist_y = jnp.zeros(())

    m_t = grad_norm + cons_x / n + L * dist_y / n
    return {
        "M_t": m_t,
        "grad_norm": grad_norm,
        "consensus_x": cons_x / n,
        "dist_y_star": dist_y,
        "stiefel_residual": _stiefel_residual(problem, x_stacked),
    }


def _stiefel_residual(problem: MinimaxProblem, x_stacked: PyTree) -> Array:
    errs = [manifolds.stiefel_error(xs).max()
            for m, xs in zip(jax.tree.leaves(problem.stiefel_mask),
                             jax.tree.leaves(x_stacked)) if m]
    if not errs:
        return jnp.zeros(())
    return jnp.max(jnp.stack(errs))
