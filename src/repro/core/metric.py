"""Convergence metric M_t (Eq. 16) and consensus diagnostics.

  M_t = || grad_x F(x_hat_t, y_bar_t) ||
      + (1/n) || x_t - x_hat_t ||
      + (L/n) || y_bar_t - y*(x_hat_t) ||

where x_hat is the per-leaf induced arithmetic mean — the geometry's
``consensus_mean`` (Eq. 9's IAM on Stiefel/Grassmann leaves, the Euclidean
mean elsewhere) — y_bar the Euclidean mean, and y* the exact inner
maximizer (closed-form for the paper's quadratic-in-y objectives).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.minimax import MinimaxProblem

Array = jax.Array
PyTree = Any


def consensus_point(problem: MinimaxProblem, x_stacked: PyTree,
                    method: str = "eigh") -> PyTree:
    """x_hat: each leaf's induced arithmetic mean over the node axis."""
    return jax.tree.map(
        lambda m, xs: m.consensus_mean(xs, method=method),
        problem.manifold_map, x_stacked)


def global_riemannian_grad(problem: MinimaxProblem, x_hat: PyTree,
                           y_bar: Array, batches: Any) -> PyTree:
    """grad_x F(x_hat, y_bar) = (1/n) sum_i grad_x f_i — Riemannian.

    ``batches`` is node-stacked local data; params are broadcast.
    """
    def one(bi):
        gx, _ = problem.grads(x_hat, y_bar, bi)
        return gx

    gx_mean = jax.tree.map(lambda g: jnp.mean(g, axis=0), jax.vmap(one)(batches))
    return jax.tree.map(lambda m, xl, gl: m.tangent_project(xl, gl),
                        problem.manifold_map, x_hat, gx_mean)


def convergence_metric(problem: MinimaxProblem, x_stacked: PyTree,
                       y_stacked: Array, batches: Any, L: float = 1.0,
                       method: str = "eigh") -> dict[str, Array]:
    """Full M_t (Eq. 16) + components.  Deliberately not fused into the
    training step — it needs an extra global grad pass; benchmarks call it
    every ``eval_every`` steps."""
    n = y_stacked.shape[0]
    x_hat = consensus_point(problem, x_stacked, method)
    y_bar = jnp.mean(y_stacked, axis=0)

    g = global_riemannian_grad(problem, x_hat, y_bar, batches)
    grad_norm = jnp.sqrt(sum(jnp.sum(l ** 2) for l in jax.tree.leaves(g)))

    cons_x = jnp.sqrt(sum(
        jnp.sum((xs - xh[None]) ** 2)
        for xs, xh in zip(jax.tree.leaves(x_stacked), jax.tree.leaves(x_hat))))

    if problem.y_star is not None:
        # exact maximizer of the *global* objective at x_hat: average the
        # closed-form per-node maximizers' defining statistics by evaluating
        # y_star on the stacked batch with broadcast params.
        y_opt = problem.y_star(x_hat, batches)
        dist_y = jnp.linalg.norm(y_bar - y_opt)
    else:
        dist_y = jnp.zeros(())

    m_t = grad_norm + cons_x / n + L * dist_y / n
    return {
        "M_t": m_t,
        "grad_norm": grad_norm,
        "consensus_x": cons_x / n,
        "dist_y_star": dist_y,
        # feasibility residual over constrained leaves; key kept under the
        # historical name for downstream readers of the metric dicts
        "stiefel_residual": _feasibility_residual(problem, x_stacked),
    }


def per_leaf_drift(problem: MinimaxProblem, x_stacked: PyTree,
                   method: str = "eigh") -> dict[str, Array]:
    """Cross-node drift per leaf: mean_i dist(x_i, x_hat) under each leaf's
    own geometry (principal angles on Grassmann, chordal on Stiefel, ...).
    Keys are '/'-joined leaf paths — the telemetry dashboard streams these
    next to the Euclidean consensus term of M_t."""
    out: dict[str, Array] = {}
    m_leaves = jax.tree_util.tree_flatten_with_path(problem.manifold_map)[0]
    x_leaves = jax.tree.leaves(x_stacked)
    for (path, m), xs in zip(m_leaves, x_leaves):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path) or "param"
        x_hat = m.consensus_mean(xs, method=method)
        out[name] = jnp.mean(jax.vmap(lambda xi: m.dist(xi, x_hat))(xs))
    return out


def _feasibility_residual(problem: MinimaxProblem, x_stacked: PyTree) -> Array:
    errs = [jnp.max(m.check(xs))
            for m, xs in zip(jax.tree.leaves(problem.manifold_map),
                             jax.tree.leaves(x_stacked))
            if m.name != "euclidean"]
    if not errs:
        return jnp.zeros(())
    return jnp.max(jnp.stack(errs))
