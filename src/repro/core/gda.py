"""DRGDA / DRSGDA — Algorithms 1 & 2 of Wu, Hu & Huang (AAAI 2023).

One jitted SPMD step implements, for every node i (leading axis of every
state leaf, vmapped / sharded over the mesh ``node`` axis):

  x_{t+1}^i = R_{x_t^i}( P_{T_x}( alpha * [W^k x_t]_i ) - beta * P_{T_x}(u_t^i) )
  y_{t+1}^i = Proj_Y( [W^k y_t]_i + eta * v_t^i )
  u_{t+1}^i = [W^k u_t]_i + grad_x f_i(x_{t+1}, y_{t+1}; B_{t+1})
                          - grad_x f_i(x_t,     y_t;     B_t)
  v_{t+1}^i = [W   v_t]_i + grad_y f_i(x_{t+1}, y_{t+1}; B_{t+1})
                          - grad_y f_i(x_t,     y_t;     B_t)

Deterministic (DRGDA) and stochastic (DRSGDA) share this skeleton — the only
difference is whether ``batch`` is the node's full local dataset every step
(Alg. 1) or a fresh minibatch (Alg. 2).  Both are exposed as named classes so
experiments read like the paper.

Faithfulness notes
------------------
* Trackers ``u`` are mixed with W^k (step 6) but ``v`` with a single W hop
  (step 7) — we follow the algorithm as printed.
* ``grad_x f_i`` entering the tracker is the Riemannian gradient at its own
  base point (tangent-projected once, at evaluation); the tracker itself is
  mixed in ambient coordinates and re-projected only inside the x-update
  (step 4) — exactly the paper's "project only at step 4" remark.
* The x-update is geometry-generic: each leaf's manifold (from
  ``MinimaxProblem.manifold_map``, see :mod:`repro.geometry`) supplies the
  tangent projection, the consensus direction and the retraction.  Stiefel
  leaves reproduce the paper's update exactly; Euclidean leaves collapse to
  the specialization x <- x + alpha([Wx]_i - x) - beta u (GT-GDA's update;
  with alpha = 1 the classic gradient-tracking consensus step); Grassmann /
  oblique / sphere leaves run the same skeleton with their own geometry.
* ``GDAHyper.retraction="polar_fused"`` routes Stiefel leaves through the
  fused Pallas retraction kernel (tangent-project + Gram + Newton--Schulz +
  apply in one VMEM pass): the ambient direction alpha*[W^k x]_i - beta*u
  is handed to the kernel, which projects internally — valid because the
  tangent projection is linear and P_x(x) = 0.
* The y-update adds an explicit projection onto Y (the paper states
  y in Y compact convex; its analysis needs feasible iterates).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.comms import layer as comms_layer
from repro.core.gossip import GossipSpec
from repro.core.minimax import MinimaxProblem
from repro.obs import wire as obs_wire

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class GDAHyper:
    """Tuning parameters {alpha, beta, eta} of Algorithms 1/2."""
    alpha: float = 0.5          # consensus step size (<= 1/M, M retraction bound)
    beta: float = 0.01          # descent step size for x
    eta: float = 0.05           # ascent step size for y
    # "polar" (paper default) | "qr" | "cayley" | "polar_fused" (fused
    # Pallas kernel); resolved per leaf — geometries that don't implement
    # the named kind fall back to their own default retraction.
    retraction: str = "polar"
    invsqrt: str = "ns"         # "ns" (TPU, Newton-Schulz) | "eigh" (oracle)
    k_override: Optional[int] = None  # gossip steps; None -> GossipSpec.k


class GDAState(NamedTuple):
    x: PyTree          # node-stacked min parameters (leaf axis 0 = node)
    y: Array           # node-stacked max variable, (n, ...)
    u: PyTree          # gradient tracker for x (ambient coords)
    v: Array           # gradient tracker for y
    gx_prev: PyTree    # last Riemannian grad_x (per node, own batch)
    gy_prev: Array     # last grad_y
    step: Array        # scalar int32
    comm: Any = None   # comms_layer.CommState when GossipSpec.comm is enabled
    obs: Any = None    # packed f32[6] counter leaf when telemetry is enabled


class StepMetrics(NamedTuple):
    loss: Array                # mean local loss at (x_{t+1}, y_{t+1})
    grad_norm_x: Array         # mean ||grad_x f_i||
    grad_norm_y: Array
    consensus_x: Array         # mean_i ||x_i - x_bar||^2 (Euclidean, cheap)
    consensus_y: Array
    tracker_norm_u: Array


class DecentralizedGDA:
    """Shared engine for DRGDA (deterministic) and DRSGDA (stochastic)."""

    #: subclasses override for reporting
    name = "gda"
    deterministic = True

    def __init__(self, problem: MinimaxProblem, gossip: GossipSpec,
                 hyper: GDAHyper = GDAHyper(), telemetry=None):
        from repro.geometry import base as _gbase
        self.problem = problem
        self.gossip = gossip
        self.hyper = hyper
        # typo guard: per-leaf resolution falls back silently (one config
        # string drives mixed pytrees), so reject globally-unknown names here
        _gbase.check_retraction_name(hyper.retraction)
        self.k = hyper.k_override if hyper.k_override is not None else gossip.k
        # how every mix executes (stacked roll/einsum or shard_map ppermute);
        # the optimizer math below never sees the difference
        self.backend = comms_layer.resolve_backend(gossip)
        self.engine = comms_layer.maybe_engine(gossip, backend=self.backend)
        if self.engine is not None:
            # the elastic join protocol projects a rejoining node's
            # consensus-mean x re-init through the problem's geometry
            self.engine.register_manifolds({"x": problem.manifold_map})
        # static config captured by the jitted closure, like the engine;
        # None (or enabled=False) compiles the exact pre-obs program
        self.telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None

    # -- initialization -----------------------------------------------------
    def init(self, x0: PyTree, y0: Array, batch0: Any) -> GDAState:
        """x0/y0 node-stacked; u_0 = grad_x f_i(x_0, y_0; B_0), v_0 likewise.

        ``u``/``gx_prev`` (and ``v``/``gy_prev``) start equal but must be
        DISTINCT buffers — the jitted step donates the whole state, and XLA
        rejects donating one buffer twice."""
        x0, y0 = _strong(x0), _strong(y0)
        rgx, gy = jax.vmap(self.problem.rgrads)(x0, y0, batch0)
        comm0 = comms_layer.maybe_init_state(
            self.engine, {"x": x0, "y": y0, "u": rgx, "v": gy})
        obs0 = self.telemetry.init_counters() if self.telemetry else None
        return GDAState(x=x0, y=y0, u=rgx, v=gy,
                        gx_prev=_copy_tree(rgx), gy_prev=jnp.copy(gy),
                        step=jnp.zeros((), jnp.int32), comm=comm0, obs=obs0)

    # -- one step -----------------------------------------------------------
    def step(self, state: GDAState, batch: Any) -> tuple[GDAState, StepMetrics]:
        h, k = self.hyper, self.k
        mix, comm_final = comms_layer.make_mixer(
            self.gossip, self.engine, state.comm, state.step,
            backend=self.backend)
        mix, obs_final = obs_wire.wrap_mixer(
            mix, state.obs, self.gossip, self.engine, self.backend,
            state.comm, state.step)

        # ---- step 4: Riemannian consensus + tracked descent on x ----------
        mixed_x = mix("x", state.x, k)

        def leaf_update(m, x, mx, u):
            kind = m.resolve_retraction(h.retraction)
            if kind == m.fused_retraction:
                # fused path: hand the AMBIENT direction to the kernel — the
                # tangent projection is linear with P_x(x) = 0, so
                # P(alpha*mx - beta*u) == alpha*P(mx) - beta*P(u).
                return m.retract(x, h.alpha * mx - h.beta * u, kind)
            return m.descent_update(x, mx, u, alpha=h.alpha, beta=h.beta,
                                    kind=kind,
                                    **({"method": h.invsqrt}
                                       if kind == "polar" else {}))

        x_new = jax.tree.map(leaf_update, self.problem.manifold_map,
                             state.x, mixed_x, state.u)

        # ---- step 5: Euclidean consensus + tracked ascent on y ------------
        y_new = jax.vmap(self.problem.project_y)(
            mix("y", state.y, k) + h.eta * state.v)

        # ---- steps 6/7: gradient tracking ----------------------------------
        (loss_new, (rgx_new, gy_new)) = _vmapped_loss_and_rgrads(
            self.problem, x_new, y_new, batch)

        u_new = jax.tree.map(lambda mu, g, gp: mu + g - gp,
                             mix("u", state.u, k), rgx_new, state.gx_prev)
        v_new = mix("v", state.v, 1) + gy_new - state.gy_prev

        obs_new = obs_final()
        if self.telemetry is not None:
            self.telemetry.flush_counters(obs_new, state.step + 1)
        new_state = GDAState(x=x_new, y=y_new, u=u_new, v=v_new,
                             gx_prev=rgx_new, gy_prev=gy_new,
                             step=state.step + 1, comm=comm_final(),
                             obs=obs_new)
        metrics = StepMetrics(
            loss=jnp.mean(loss_new),
            grad_norm_x=_tree_mean_norm(rgx_new),
            grad_norm_y=jnp.mean(jnp.linalg.norm(
                gy_new.reshape(gy_new.shape[0], -1), axis=-1)),
            consensus_x=_tree_consensus(x_new),
            consensus_y=_consensus(y_new),
            tracker_norm_u=_tree_mean_norm(u_new),
        )
        return new_state, metrics

    def make_step(self, donate: bool = True) -> Callable:
        """jitted step closure (state, batch) -> (state, metrics)."""
        return make_obs_step(self.step, self.telemetry, donate=donate)


class DRGDA(DecentralizedGDA):
    """Algorithm 1 — deterministic decentralized Riemannian GDA.

    Call :meth:`step` with each node's **full local dataset** every
    iteration.  Gradient complexity O(eps^-2) (Theorem 1).
    """
    name = "drgda"
    deterministic = True


class DRSGDA(DecentralizedGDA):
    """Algorithm 2 — stochastic decentralized Riemannian GDA.

    Call :meth:`step` with a fresh i.i.d. minibatch B_{t+1} per node each
    iteration.  Sample complexity O(eps^-4) (Theorem 2).
    """
    name = "drsgda"
    deterministic = False


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def make_obs_step(step_fn: Callable, telemetry, donate: bool = True,
                  counter=None) -> Callable:
    """jit ``step_fn`` with the telemetry flush hoisted to host cadence.

    A jitted program containing an io_callback loses fast-path dispatch on
    EVERY call, even when a ``lax.cond`` guards the callback — so with
    telemetry on we compile two executables from the same trace: a quiet
    effect-free one (ordinary steps, async dispatch intact) and a flushing
    one routed to every ``flush_every``-th call by a host-side counter.
    Both are fully fused; the math is identical (test-enforced bit
    identity).  ``counter`` shares one cadence across multiple step
    functions (GT-SRVR's step + anchor_step).
    """
    donate_args = (0,) if donate else ()
    if telemetry is None:
        return jax.jit(step_fn, donate_argnums=donate_args)

    def stepper(state, batch, flush: bool):
        with telemetry.flush_mode("always" if flush else "never"):
            return step_fn(state, batch)

    jitted = jax.jit(stepper, static_argnums=(2,), donate_argnums=donate_args)
    counter = counter if counter is not None else itertools.count(1)

    def run(state, batch):
        # flush on the very first call too: it compiles the flushing
        # executable up front (no mid-run compile stall at step flush_every)
        # and doubles as a telemetry-alive record
        n = next(counter)
        return jitted(state, batch,
                      n == 1 or n % telemetry.flush_every == 0)

    return run


def _copy_tree(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.copy, tree)


def _strong(tree: PyTree) -> PyTree:
    """Strip weak types from user-supplied init leaves (e.g. a
    ``jnp.full(..., 1.0/G)`` y0).  A weak-typed leaf in the init state gives
    the jitted step different input avals on call one vs call two — i.e. a
    silent second compile mid-training."""
    return jax.tree.map(lambda l: jnp.asarray(l).astype(jnp.asarray(l).dtype),
                        tree)


def _vmapped_loss_and_rgrads(problem: MinimaxProblem, x, y, batch):
    def one(xi, yi, bi):
        loss, (gx, gy) = jax.value_and_grad(problem.loss_fn, argnums=(0, 1))(xi, yi, bi)
        rgx = jax.tree.map(lambda m, xl, gl: m.tangent_project(xl, gl),
                           problem.manifold_map, xi, gx)
        return loss, (rgx, gy)
    return jax.vmap(one)(x, y, batch)


def _tree_mean_norm(tree: PyTree) -> Array:
    sq = sum(jnp.sum(l.reshape(l.shape[0], -1) ** 2, axis=-1)
             for l in jax.tree.leaves(tree))
    return jnp.mean(jnp.sqrt(sq))


def _consensus(x: Array) -> Array:
    xb = jnp.mean(x, axis=0, keepdims=True)
    return jnp.mean(jnp.sum((x - xb).reshape(x.shape[0], -1) ** 2, axis=-1))


def _tree_consensus(tree: PyTree) -> Array:
    return sum(_consensus(l) for l in jax.tree.leaves(tree))


def broadcast_to_nodes(tree: PyTree, n: int) -> PyTree:
    """Replicate single-node params to the node-stacked layout (common init:
    'initialize local model parameters ... with the same points')."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), tree)
