"""Comparison baselines used in the paper's experiments.

The paper compares against Euclidean decentralized minimax methods, adding a
projection-like retraction so they respect the Stiefel constraint
("Since these methods were not designed for optimization on the Stiefel
manifold, we add the retraction operation"):

* **GT-GDA**   (Zhang et al. 2021)  — deterministic gradient-tracking GDA.
* **GNSD-A**   (motivated by GNSD, Lu et al. 2019) — stochastic
  gradient-tracking descent ascent.
* **DM-HSGD**  (Xian et al. 2021)  — hybrid (STORM) variance-reduced
  decentralized minimax.
* **GT-SRVR**  (Zhang et al. 2021) — SPIDER/SVRG-style recursive variance
  reduction with periodic anchor batches + gradient tracking.

All share the node-stacked pytree layout of :mod:`repro.core.gda`.
Constrained leaves are *projected back* onto their manifold (polar factor on
Stiefel/Grassmann, column normalization on oblique — each geometry's
``project``) after the Euclidean update — i.e. the update direction is NOT
tangent-projected, which is precisely what distinguishes them from
DRGDA/DRSGDA and what the paper's figures show costs them convergence speed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.comms import layer as comms_layer
from repro.core.gda import (GDAHyper, StepMetrics, _consensus, _copy_tree,
                            _strong,
                            _tree_consensus, _tree_mean_norm,
                            make_obs_step)
from repro.core.gossip import GossipSpec
from repro.core.minimax import MinimaxProblem
from repro.obs import wire as obs_wire

Array = jax.Array
PyTree = Any


def _project_back(manifold_map: PyTree, x: PyTree, method: str = "ns") -> PyTree:
    return jax.tree.map(lambda m, xi: m.project(xi, method=method),
                        manifold_map, x)


def _euclid_grads(problem: MinimaxProblem, x, y, batch):
    """vmapped (loss, (gx, gy)) — *Euclidean* grads (no tangent projection)."""
    def one(xi, yi, bi):
        return jax.value_and_grad(problem.loss_fn, argnums=(0, 1))(xi, yi, bi)
    return jax.vmap(one)(x, y, batch)


def _metrics(loss, gx, gy, x, y, u) -> StepMetrics:
    return StepMetrics(
        loss=jnp.mean(loss),
        grad_norm_x=_tree_mean_norm(gx),
        grad_norm_y=jnp.mean(jnp.linalg.norm(gy.reshape(gy.shape[0], -1), axis=-1)),
        consensus_x=_tree_consensus(x),
        consensus_y=_consensus(y),
        tracker_norm_u=_tree_mean_norm(u),
    )


# ---------------------------------------------------------------------------
# GT-GDA / GNSD-A : gradient tracking descent ascent (+ projection)
# ---------------------------------------------------------------------------


class GTState(NamedTuple):
    x: PyTree
    y: Array
    u: PyTree
    v: Array
    gx_prev: PyTree
    gy_prev: Array
    step: Array
    comm: Any = None
    obs: Any = None


class GTGDA:
    """Euclidean gradient-tracking GDA with post-hoc Stiefel projection.

    Deterministic when fed full local batches (GT-GDA); the stochastic
    variant fed minibatches is the paper's GNSD-A baseline (see alias).
    """
    name = "gt-gda"
    deterministic = True

    def __init__(self, problem: MinimaxProblem, gossip: GossipSpec,
                 hyper: GDAHyper = GDAHyper(), telemetry=None):
        self.problem, self.gossip, self.hyper = problem, gossip, hyper
        self.backend = comms_layer.resolve_backend(gossip)
        self.engine = comms_layer.maybe_engine(gossip, backend=self.backend)
        if self.engine is not None:
            # elastic join protocol: project rejoined x through the geometry
            self.engine.register_manifolds({"x": problem.manifold_map})
        self.telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None

    def init(self, x0: PyTree, y0: Array, batch0: Any) -> GTState:
        x0, y0 = _strong(x0), _strong(y0)
        _, (gx, gy) = _euclid_grads(self.problem, x0, y0, batch0)
        comm0 = comms_layer.maybe_init_state(
            self.engine, {"x": x0, "y": y0, "u": gx, "v": gy})
        obs0 = self.telemetry.init_counters() if self.telemetry else None
        return GTState(x0, y0, gx, gy, _copy_tree(gx), jnp.copy(gy),
                       jnp.zeros((), jnp.int32), comm0, obs0)

    def step(self, state: GTState, batch: Any) -> tuple[GTState, StepMetrics]:
        h = self.hyper
        mix, comm_final = comms_layer.make_mixer(
            self.gossip, self.engine, state.comm, state.step,
            backend=self.backend)
        mix, obs_final = obs_wire.wrap_mixer(
            mix, state.obs, self.gossip, self.engine, self.backend,
            state.comm, state.step)
        x_new = jax.tree.map(lambda mx, u: mx - h.beta * u,
                             mix("x", state.x, 1), state.u)
        x_new = _project_back(self.problem.manifold_map, x_new, h.invsqrt)
        y_new = jax.vmap(self.problem.project_y)(
            mix("y", state.y, 1) + h.eta * state.v)

        loss, (gx, gy) = _euclid_grads(self.problem, x_new, y_new, batch)
        u_new = jax.tree.map(lambda mu, g, gp: mu + g - gp,
                             mix("u", state.u, 1), gx, state.gx_prev)
        v_new = mix("v", state.v, 1) + gy - state.gy_prev
        obs_new = obs_final()
        if self.telemetry is not None:
            self.telemetry.flush_counters(obs_new, state.step + 1)
        new = GTState(x_new, y_new, u_new, v_new, gx, gy, state.step + 1,
                      comm_final(), obs_new)
        return new, _metrics(loss, gx, gy, x_new, y_new, u_new)

    def make_step(self, donate: bool = True):
        return make_obs_step(self.step, self.telemetry, donate=donate)


class GNSDA(GTGDA):
    """GNSD-A — GT-GDA's skeleton driven by stochastic minibatches."""
    name = "gnsd-a"
    deterministic = False


# ---------------------------------------------------------------------------
# DM-HSGD : hybrid stochastic gradient descent ascent (STORM estimator)
# ---------------------------------------------------------------------------


class HSGDState(NamedTuple):
    x: PyTree
    y: Array
    x_prev: PyTree
    y_prev: Array
    dx: PyTree     # STORM estimator for grad_x
    dy: Array
    step: Array
    comm: Any = None
    obs: Any = None


@dataclasses.dataclass(frozen=True)
class HSGDHyper:
    beta: float = 0.01
    eta: float = 0.05
    bx: float = 0.1      # STORM momentum for x (paper tunes {0.1, 0.9})
    by: float = 0.1
    invsqrt: str = "ns"


class DMHSGD:
    """DM-HSGD (Xian et al. 2021) + Stiefel projection.

    STORM/hybrid estimator: d_t = g(w_t; B_t) + (1-b)(d_{t-1} - g(w_{t-1}; B_t))
    — both evaluations on the SAME batch B_t (two grad passes per step).
    """
    name = "dm-hsgd"
    deterministic = False

    def __init__(self, problem: MinimaxProblem, gossip: GossipSpec,
                 hyper: HSGDHyper = HSGDHyper(), telemetry=None):
        self.problem, self.gossip, self.hyper = problem, gossip, hyper
        self.backend = comms_layer.resolve_backend(gossip)
        self.engine = comms_layer.maybe_engine(gossip, backend=self.backend)
        if self.engine is not None:
            # elastic join protocol: project rejoined x through the geometry
            self.engine.register_manifolds({"x": problem.manifold_map})
        self.telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None

    def init(self, x0: PyTree, y0: Array, batch0: Any) -> HSGDState:
        x0, y0 = _strong(x0), _strong(y0)
        _, (gx, gy) = _euclid_grads(self.problem, x0, y0, batch0)
        comm0 = comms_layer.maybe_init_state(
            self.engine, {"x": x0, "y": y0, "u": gx, "v": gy})
        obs0 = self.telemetry.init_counters() if self.telemetry else None
        return HSGDState(x0, y0, _copy_tree(x0), jnp.copy(y0), gx, gy,
                         jnp.zeros((), jnp.int32), comm0, obs0)

    def step(self, state: HSGDState, batch: Any) -> tuple[HSGDState, StepMetrics]:
        h = self.hyper
        mix, comm_final = comms_layer.make_mixer(
            self.gossip, self.engine, state.comm, state.step,
            backend=self.backend)
        mix, obs_final = obs_wire.wrap_mixer(
            mix, state.obs, self.gossip, self.engine, self.backend,
            state.comm, state.step)
        loss, (gx_cur, gy_cur) = _euclid_grads(self.problem, state.x, state.y, batch)
        _, (gx_old, gy_old) = _euclid_grads(self.problem, state.x_prev, state.y_prev, batch)

        dx = jax.tree.map(lambda g, go, d: g + (1.0 - h.bx) * (d - go),
                          gx_cur, gx_old, state.dx)
        dy = gy_cur + (1.0 - h.by) * (state.dy - gy_old)
        dx = mix("u", dx, 1)
        dy = mix("v", dy, 1)

        x_new = jax.tree.map(lambda mx, d: mx - h.beta * d,
                             mix("x", state.x, 1), dx)
        x_new = _project_back(self.problem.manifold_map, x_new, h.invsqrt)
        y_new = jax.vmap(self.problem.project_y)(
            mix("y", state.y, 1) + h.eta * dy)

        obs_new = obs_final()
        if self.telemetry is not None:
            self.telemetry.flush_counters(obs_new, state.step + 1)
        new = HSGDState(x_new, y_new, state.x, state.y, dx, dy, state.step + 1,
                        comm_final(), obs_new)
        return new, _metrics(loss, gx_cur, gy_cur, x_new, y_new, dx)

    def make_step(self, donate: bool = True):
        return make_obs_step(self.step, self.telemetry, donate=donate)


# ---------------------------------------------------------------------------
# GT-SRVR : SPIDER-style recursive variance reduction + gradient tracking
# ---------------------------------------------------------------------------


class SRVRState(NamedTuple):
    x: PyTree
    y: Array
    x_prev: PyTree
    y_prev: Array
    gx_est: PyTree   # recursive estimator
    gy_est: Array
    u: PyTree        # gradient tracker on the estimator
    v: Array
    gx_est_prev: PyTree
    gy_est_prev: Array
    step: Array
    comm: Any = None
    obs: Any = None


@dataclasses.dataclass(frozen=True)
class SRVRHyper:
    beta: float = 0.01
    eta: float = 0.05
    q: int = 16          # anchor period (full/large batch every q steps)
    invsqrt: str = "ns"


class GTSRVR:
    """GT-SRVR (Zhang et al. 2021) + Stiefel projection.

    ``anchor_step`` refreshes the estimator with a large (anchor) batch;
    ``step`` applies the SPIDER recursion with same-batch grad differences.
    The driver alternates: anchor every ``hyper.q`` steps.
    """
    name = "gt-srvr"
    deterministic = False

    def __init__(self, problem: MinimaxProblem, gossip: GossipSpec,
                 hyper: SRVRHyper = SRVRHyper(), telemetry=None):
        self.problem, self.gossip, self.hyper = problem, gossip, hyper
        self.backend = comms_layer.resolve_backend(gossip)
        self.engine = comms_layer.maybe_engine(gossip, backend=self.backend)
        if self.engine is not None:
            # elastic join protocol: project rejoined x through the geometry
            self.engine.register_manifolds({"x": problem.manifold_map})
        self.telemetry = telemetry if telemetry is not None \
            and telemetry.enabled else None

    def init(self, x0: PyTree, y0: Array, anchor_batch: Any) -> SRVRState:
        x0, y0 = _strong(x0), _strong(y0)
        _, (gx, gy) = _euclid_grads(self.problem, x0, y0, anchor_batch)
        cp = _copy_tree
        comm0 = comms_layer.maybe_init_state(
            self.engine, {"x": x0, "y": y0, "u": gx, "v": gy})
        obs0 = self.telemetry.init_counters() if self.telemetry else None
        return SRVRState(x0, y0, cp(x0), jnp.copy(y0), gx, gy,
                         cp(gx), jnp.copy(gy), cp(gx), jnp.copy(gy),
                         jnp.zeros((), jnp.int32), comm0, obs0)

    def _update_params(self, state: SRVRState, gx_est, gy_est):
        h = self.hyper
        mix, comm_final = comms_layer.make_mixer(
            self.gossip, self.engine, state.comm, state.step,
            backend=self.backend)
        mix, obs_final = obs_wire.wrap_mixer(
            mix, state.obs, self.gossip, self.engine, self.backend,
            state.comm, state.step)
        u_new = jax.tree.map(lambda mu, g, gp: mu + g - gp,
                             mix("u", state.u, 1), gx_est, state.gx_est_prev)
        v_new = mix("v", state.v, 1) + gy_est - state.gy_est_prev
        x_new = jax.tree.map(lambda mx, u: mx - h.beta * u,
                             mix("x", state.x, 1), u_new)
        x_new = _project_back(self.problem.manifold_map, x_new, h.invsqrt)
        y_new = jax.vmap(self.problem.project_y)(
            mix("y", state.y, 1) + h.eta * v_new)
        obs_new = obs_final()
        if self.telemetry is not None:
            self.telemetry.flush_counters(obs_new, state.step + 1)
        return x_new, y_new, u_new, v_new, comm_final(), obs_new

    def anchor_step(self, state: SRVRState, anchor_batch: Any):
        loss, (gx, gy) = _euclid_grads(self.problem, state.x, state.y, anchor_batch)
        x_new, y_new, u_new, v_new, comm, obs = self._update_params(state, gx, gy)
        new = SRVRState(x_new, y_new, state.x, state.y, gx, gy, u_new, v_new,
                        gx, gy, state.step + 1, comm, obs)
        return new, _metrics(loss, gx, gy, x_new, y_new, u_new)

    def step(self, state: SRVRState, batch: Any):
        loss, (gx_cur, gy_cur) = _euclid_grads(self.problem, state.x, state.y, batch)
        _, (gx_old, gy_old) = _euclid_grads(self.problem, state.x_prev,
                                            state.y_prev, batch)
        gx_est = jax.tree.map(lambda g, go, e: e + g - go,
                              gx_cur, gx_old, state.gx_est)
        gy_est = state.gy_est + gy_cur - gy_old
        x_new, y_new, u_new, v_new, comm, obs = self._update_params(
            state, gx_est, gy_est)
        new = SRVRState(x_new, y_new, state.x, state.y, gx_est, gy_est,
                        u_new, v_new, gx_est, gy_est, state.step + 1, comm, obs)
        return new, _metrics(loss, gx_cur, gy_cur, x_new, y_new, u_new)

    def make_step(self, donate: bool = True):
        import itertools
        shared = itertools.count(1)   # one flush cadence across both phases
        return (make_obs_step(self.step, self.telemetry, donate=donate,
                              counter=shared),
                make_obs_step(self.anchor_step, self.telemetry, donate=donate,
                              counter=shared))


ALL_BASELINES = {c.name: c for c in (GTGDA, GNSDA, DMHSGD, GTSRVR)}
