"""Core library: the paper's contribution as composable JAX modules."""
from repro.core import baselines, gda, gossip, manifolds, metric, minimax  # noqa: F401
from repro.core.baselines import DMHSGD, GNSDA, GTGDA, GTSRVR  # noqa: F401
from repro.core.gda import DRGDA, DRSGDA, GDAHyper, GDAState  # noqa: F401
from repro.core.gossip import GossipSpec  # noqa: F401
from repro.core.minimax import MinimaxProblem  # noqa: F401

OPTIMIZERS = {
    "drgda": DRGDA,
    "drsgda": DRSGDA,
    "gt-gda": GTGDA,
    "gnsd-a": GNSDA,
    "dm-hsgd": DMHSGD,
    "gt-srvr": GTSRVR,
}
