"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth for the kernel sweep tests *and* the portable
execution path: on non-TPU backends (this CPU container, the dry-run's
512 fake host devices) ``ops.py`` dispatches here.  ``blockwise_attention``
is written with the same online-softmax streaming structure as the TPU
kernel so its memory profile (never materializes S x T scores) and its
cost_analysis FLOPs match the kernel's.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_naive(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    q_positions: Array | None = None,
                    kv_positions: Array | None = None,
                    softmax_scale: float | None = None) -> Array:
    """Reference attention, materializes full scores.  Shapes:
    q (B, S, H, hd); k/v (B, T, Hkv, hd); returns (B, S, H, hd).

    GQA: H must be a multiple of Hkv; kv heads are broadcast.
    ``*_positions``: absolute token positions (B, S) / (B, T); default
    aranges.  Masking: kv_pos <= q_pos (causal) and q_pos - kv_pos < window.
    kv positions < 0 mark empty cache slots (always masked).
    """
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    group = h // hkv
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t), (b, t))

    hdv = v.shape[-1]
    qg = q.reshape(b, s, hkv, group, hd)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32) * scale,
                        k.astype(jnp.float32))
    mask = kv_positions[:, None, :] >= 0
    if causal:
        mask &= kv_positions[:, None, :] <= q_positions[:, :, None]
    if window is not None:
        mask &= (q_positions[:, :, None] - kv_positions[:, None, :]) < window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthe->bshge", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hdv).astype(q.dtype)


def blockwise_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                        window: int | None = None,
                        q_positions: Array | None = None,
                        kv_positions: Array | None = None,
                        softmax_scale: float | None = None,
                        chunk: int = 1024) -> Array:
    """Online-softmax attention streaming over KV chunks (flash-style, pure
    jnp, compiles on any backend).  Same signature/semantics as
    :func:`attention_naive`."""
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    group = h // hkv
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)

    qf = (q.astype(jnp.float32) * scale).reshape(b, s, hkv, group, hd)
    kc = k.reshape(b, n_chunks, chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, chunk, hkv, hdv)
    pc = kv_positions.reshape(b, n_chunks, chunk)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp                      # (b, chunk, hkv, hd), (b, chunk)
        sc = jnp.einsum("bshgd,bthd->bhgst", qf, kb.astype(jnp.float32))
        mask = pb[:, None, :] >= 0
        if causal:
            mask &= pb[:, None, :] <= q_positions[:, :, None]
        if window is not None:
            mask &= (q_positions[:, :, None] - pb[:, None, :]) < window
        sc = jnp.where(mask[:, None, None, :, :], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgst,bthe->bhgse", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, s, hdv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, hdv)
    return out.astype(q.dtype)


def paged_decode_attention_ref(q: Array, k_pages: Array, v_pages: Array,
                               block_table: Array, seq_lens: Array, *,
                               window: int | None = None,
                               softmax_scale: float | None = None) -> Array:
    """Oracle for the paged-decode kernel: gather every slot's pages through
    the block table into a contiguous (S, M*ps, Hkv, hd) view, then run the
    streaming attention oracle with positions derived from the page layout.

    q (S, H, hd) — one query token per slot; pools (P, ps, Hkv, hd/hdv);
    block_table (S, M) int32 (-1 = unallocated, clamped to page 0 and fully
    masked); seq_lens (S,) int32 — valid tokens, query at ``seq_lens - 1``.
    A slot with ``seq_lens == 0`` returns exact zeros (all keys masked).
    """
    s_slots = q.shape[0]
    ps, hkv, hd = k_pages.shape[1:]
    hdv = v_pages.shape[-1]
    m_pages = block_table.shape[1]
    bt = jnp.maximum(block_table, 0)
    k = k_pages[bt].reshape(s_slots, m_pages * ps, hkv, hd)
    v = v_pages[bt].reshape(s_slots, m_pages * ps, hkv, hdv)
    pos = jnp.arange(m_pages * ps, dtype=jnp.int32)[None, :]
    kv_pos = jnp.where(pos < seq_lens[:, None], pos, -1)
    q_pos = seq_lens[:, None].astype(jnp.int32) - 1
    out = blockwise_attention(q[:, None], k, v, causal=True, window=window,
                              q_positions=q_pos, kv_positions=kv_pos,
                              softmax_scale=softmax_scale)[:, 0]
    # fully-masked slots: the streaming softmax degenerates to a mean over
    # dump-page values; pin them to the kernel's exact-zero convention
    return jnp.where(seq_lens[:, None, None] > 0, out,
                     jnp.zeros_like(out))


# ---------------------------------------------------------------------------
# Stiefel tangent projection
# ---------------------------------------------------------------------------


def stiefel_project_ref(x: Array, g: Array) -> Array:
    """P_{T_x}(g) = g - x sym(x^T g)  over the last two dims."""
    xtg = jnp.einsum("...dr,...ds->...rs", x, g)
    s = 0.5 * (xtg + jnp.swapaxes(xtg, -1, -2))
    return g - jnp.einsum("...dr,...rs->...ds", x, s)


# ---------------------------------------------------------------------------
# fused polar retraction (tangent project + Gram + NS inverse sqrt + apply)
# ---------------------------------------------------------------------------


def fused_retract_ref(x: Array, g: Array, ns_iters: int = 20) -> Array:
    """R_x(P_x(g)): polar retraction of the tangent-projected AMBIENT
    direction — the fused kernel's semantics, in streaming-free jnp.
    Same math sequence (the geometry layer's coupled Newton--Schulz
    inverse sqrt), so FLOP structure matches."""
    from repro.geometry.stiefel import _invsqrt_newton_schulz

    u = stiefel_project_ref(x, g)
    r = u.shape[-1]
    utu = jnp.einsum("...dr,...ds->...rs", u, u)
    a = jnp.eye(r, dtype=jnp.float32) + utu.astype(jnp.float32)
    inv = _invsqrt_newton_schulz(a, ns_iters)
    return jnp.einsum("...dr,...rs->...ds", (x + u).astype(jnp.float32),
                      inv).astype(x.dtype)


# ---------------------------------------------------------------------------
# ring gossip mix
# ---------------------------------------------------------------------------


def ring_mix_ref(x_self: Array, x_left: Array, x_right: Array,
                 w_self: float, w_side: float) -> Array:
    """One gossip hop's local combine: wc*x + ws*(left + right)."""
    return w_self * x_self + w_side * (x_left + x_right)


# ---------------------------------------------------------------------------
# fused multi-hop ring mix (halo-panel megakernel)
# ---------------------------------------------------------------------------


def _panel_hop(z: Array, w_self: float, w_side: float) -> Array:
    """One ring combine on the *interior* rows of a halo panel: row ``i``'s
    neighbours are rows ``i-1`` / ``i+1``, and the result drops the two
    boundary rows (they have no valid neighbour on one side).  Per-element
    this is the same ``wc*x + ws*(l + r)`` expression as ``ring_mix_ref``;
    the shrinking "pyramid" does only the row work that can still reach the
    center — exactly ``halo >= hops`` wide — instead of combining garbage
    panel ends that get sliced away anyway."""
    return w_self * z[1:-1] + w_side * (z[:-2] + z[2:])


def _panel_hop_dq(q: Array, s: Array, w_self: float, w_side: float) -> Array:
    """One ring combine on quantized panel values with per-row scales,
    dequantizing each shifted operand separately — the same dataflow as
    ``quant_mix_ref`` and the ``multi_hop_mix_quant_flat`` kernel, so the
    oracle and the megakernel agree bitwise under jit (cross-backend
    results agree to FMA rounding of the combines)."""
    def shift_down(z):
        return jnp.concatenate([jnp.zeros_like(z[:1]), z[:-1]], axis=0)

    def shift_up(z):
        return jnp.concatenate([z[1:], jnp.zeros_like(z[:1])], axis=0)

    return (w_self * (q * s)
            + w_side * (shift_down(q) * shift_down(s)
                        + shift_up(q) * shift_up(s)))


def multi_hop_mix_ref(panel: Array, *, hops: int, out_rows: int, halo: int,
                      w_self: float, w_side: float) -> Array:
    """``hops`` fused ring combines over a ``(halo + b + halo, F)`` panel;
    returns the exact center ``(out_rows, F)`` rows (``halo >= hops``).
    Each hop shrinks the live window by one row per side, so the center
    starts at ``halo - hops`` in the final window."""
    z = panel.astype(jnp.float32)
    for _ in range(hops):
        z = _panel_hop(z, w_self, w_side)
    lo = halo - hops
    return z[lo:lo + out_rows].astype(panel.dtype)


def multi_hop_mix_quant_ref(q_panel: Array, s_panel: Array, *, hops: int,
                            w_self: float, w_side: float) -> Array:
    """All-hop compressed schedule on an int8 halo panel: hop 0 fuses
    dequantize + combine, every later hop requantizes deterministically
    (round-to-nearest, per-row max-abs/127 scale, 1e-12 floor — mirrors
    ``comms.compress.quantize_det``) before combining.  Returns the full
    evolved f32 panel (callers slice the center rows), matching
    ``multi_hop_mix_quant_flat``."""
    z = _panel_hop_dq(q_panel.astype(jnp.float32),
                      s_panel.astype(jnp.float32), w_self, w_side)
    for _ in range(1, hops):
        amax = jnp.max(jnp.abs(z), axis=1, keepdims=True)
        scale = jnp.maximum(amax / 127.0, 1e-12)
        q = jnp.clip(jnp.round(z / scale), -127.0, 127.0)
        z = _panel_hop_dq(q, scale, w_self, w_side)
    return z


# ---------------------------------------------------------------------------
# fused dequantize + ring combine
# ---------------------------------------------------------------------------


def quant_mix_ref(q_self: Array, q_left: Array, q_right: Array,
                  s_self: Array, s_left: Array, s_right: Array,
                  w_self: float, w_side: float,
                  out_dtype=jnp.float32) -> Array:
    """Compressed gossip hop's combine on int8 payloads with per-row scales:
    out = wc * dq(qc) + ws * (dq(ql) + dq(qr)), dq(q) = q * scale."""
    def dq(q, s):
        return q.astype(jnp.float32) * s.astype(jnp.float32)

    return (w_self * dq(q_self, s_self)
            + w_side * (dq(q_left, s_left) + dq(q_right, s_right))
            ).astype(out_dtype)
