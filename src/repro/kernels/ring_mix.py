"""Ring-gossip local combine Pallas kernel.

After the two ``collective-permute``s of one gossip hop deliver the left and
right neighbour tensors, each device combines

    out = w_self * x + w_side * (x_left + x_right)

This is a pure-bandwidth elementwise op; the kernel tiles flat (N,) data as
(rows, 1024) VMEM panels so HBM reads stream at full width.  Trivial but it
anchors the collective-compute overlap experiments in §Perf (the combine can
run on the already-arrived buffer while the next permute is in flight).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANE = 1024
DEFAULT_BLOCK_ROWS = 256


def _mix_kernel(xc_ref, xl_ref, xr_ref, o_ref, *, w_self: float, w_side: float):
    o_ref[...] = (w_self * xc_ref[...].astype(jnp.float32)
                  + w_side * (xl_ref[...].astype(jnp.float32)
                              + xr_ref[...].astype(jnp.float32))
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("w_self", "w_side", "block_rows", "interpret"))
def ring_mix_flat(x_self: Array, x_left: Array, x_right: Array, *,
                  w_self: float, w_side: float,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False) -> Array:
    """Inputs: flat 2-D (rows, LANE) panels, rows % block_rows == 0.

    Tiling contract (callers pad — see ``ops.ring_mix``): the grid covers
    the panel exactly, so rows must be a multiple of the block."""
    rows, lane = x_self.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(
            f"ring_mix_flat: rows={rows} not a multiple of "
            f"block_rows={block_rows}; pad the row tail (ops.ring_mix does)")
    kernel = functools.partial(_mix_kernel, w_self=w_self, w_side=w_side)
    spec = pl.BlockSpec((block_rows, lane), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x_self.shape, x_self.dtype),
        interpret=interpret,
        name="ring_mix",
    )(x_self, x_left, x_right)
