"""Fused multi-hop ring-gossip megakernel.

One ``pallas_call`` executes the *entire* local work of a k-hop ``W^k``
ring schedule.  The hop-by-hop ``ShardMapBackend`` path pays k ppermute
launches plus k combine launches per mix; the bench shows that launch
latency — not bytes — is what loses to the stacked backend (127 vs 998
hops/sec at 64k params/node).  This kernel collapses the schedule:

halo formulation
  The caller (``ShardMapBackend._gather_halo``) prepends/appends ``halo``
  neighbour rows to the local ``b``-row node block, giving a
  ``(halo + b + halo, F)`` panel in which row ``i``'s ring neighbours are
  simply rows ``i-1`` / ``i+1``.  All ``hops <= halo`` combines then run
  **locally** with zero wire events as a shrinking "pyramid": each hop
  combines only the interior rows,

      z <- wc * z[1:-1] + ws * (z[:-2] + z[2:])

  dropping the two boundary rows (which have no valid neighbour on one
  side).  After ``hops`` hops the window is exactly the rows a valid
  ``hops``-deep dependency cone can produce, and the center rows are
  bit-exact — per-element the expression is the same f32
  ``wc*x + ws*(l + r)`` as ``ring_mix`` / the stacked ``mix_ring`` leaf,
  which is what keeps the cross-backend bit-identity contract of
  ``test_mix_backend_equiv.py`` intact.  (The pyramid also does only the
  row work that can reach the center — no combines on panel-end garbage.)

fp32 variant (``multi_hop_mix_flat``)
  Single-pass grid over feature blocks: the panel's rows all fit one block
  (``b + 2*halo`` is small), so each grid step loads a ``(rows, block_f)``
  tile, runs every hop in VMEM, and writes only the ``out_rows`` center
  rows — one panel read + one block write total, versus 2k HBM round
  trips for the unfused schedule.

int8 variant (``multi_hop_mix_quant_flat``)
  The all-hop compressed schedule: the panel arrives as int8 payloads with
  one f32 scale per row (only those bytes crossed the wire), hop 0 fuses
  dequantize + combine, and every later hop *re-quantizes* its input
  deterministically (round-to-nearest, per-row max-abs/127 scale — the
  values a receiver would have decoded had that hop's rows been shipped as
  int8).  Per-row maxima need the full row, so this variant uses the
  two-pass revisiting-grid trick from ``retract.py``: the f32 state lives
  in the output ref (revisited per stage), a max-accumulate stage reduces
  row maxima into VMEM scratch across feature blocks, and the following
  stage requantizes + combines.  Quantization math is kept expression-
  identical to ``comms.compress.quantize_det`` so the stacked backend's
  hop-by-hop oracle decodes the same int8 values at every hop (results
  agree to FMA rounding of the final combines).

``kernels/ref.py`` holds the jnp oracles; ``ops.multi_hop_mix`` /
``ops.multi_hop_mix_quant`` own dispatch, padding and blocking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_F = 1024
_EPS = 1e-12   # same scale floor as comms.compress


def _hop(z: Array, wc: float, ws: float) -> Array:
    """One ring combine on the interior rows of a panel value (row i sees
    rows i-1 / i+1; the two boundary rows drop out) — the shrinking
    "pyramid": only rows that can still influence the center are combined,
    and no zero-padding concats are materialized.  Mirrors ``_panel_hop``
    in ``kernels/ref.py`` so interpret mode stays bitwise with the oracle."""
    return wc * z[1:-1] + ws * (z[:-2] + z[2:])


def _shift_down(z: Array) -> Array:
    """Row i-1's value at row i; zeros shifted in at the top."""
    return jnp.concatenate([jnp.zeros_like(z[:1]), z[:-1]], axis=0)


def _shift_up(z: Array) -> Array:
    """Row i+1's value at row i; zeros shifted in at the bottom."""
    return jnp.concatenate([z[1:], jnp.zeros_like(z[:1])], axis=0)


def _hop_dq(q: Array, s: Array, wc: float, ws: float) -> Array:
    """One ring combine on quantized panel values with per-row scales,
    dequantizing each shifted operand separately —
    ``wc*dq(q_i) + ws*(dq(q_{i-1}) + dq(q_{i+1}))``, the same dataflow as
    ``quant_mix_ref`` / ``multi_hop_mix_quant_ref`` (so kernel and oracle
    agree bitwise under jit; cross-backend results agree to FMA rounding)."""
    dq = q * s
    dq_l = _shift_down(q) * _shift_down(s)
    dq_r = _shift_up(q) * _shift_up(s)
    return wc * dq + ws * (dq_l + dq_r)


# ---------------------------------------------------------------------------
# fp32 megakernel — single pass
# ---------------------------------------------------------------------------


def _mhm_kernel(x_ref, o_ref, *, hops: int, halo: int, w_self: float,
                w_side: float):
    z = x_ref[...].astype(jnp.float32)
    for _ in range(hops):
        z = _hop(z, w_self, w_side)
    out_rows = o_ref.shape[0]
    lo = halo - hops                 # each hop dropped one row per side
    o_ref[...] = z[lo:lo + out_rows].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("hops", "out_rows", "halo",
                                             "w_self", "w_side", "block_f",
                                             "interpret"))
def multi_hop_mix_flat(panel: Array, *, hops: int, out_rows: int, halo: int,
                       w_self: float, w_side: float,
                       block_f: int = DEFAULT_BLOCK_F,
                       interpret: bool = False) -> Array:
    """``hops`` fused ring combines on a ``(halo + b + halo [+ pad], F)``
    panel; returns the ``(out_rows, F)`` center rows.  ``F % block_f == 0``
    (ops.py pads); requires ``halo >= hops`` for exact output."""
    rows, f = panel.shape
    block_f = min(block_f, f)
    if f % block_f:
        raise ValueError(f"multi_hop_mix_flat: F={f} not a multiple of "
                         f"block_f={block_f}; pad the lane tail "
                         f"(ops.multi_hop_mix does)")
    kernel = functools.partial(_mhm_kernel, hops=hops, halo=halo,
                               w_self=w_self, w_side=w_side)
    return pl.pallas_call(
        kernel,
        grid=(f // block_f,),
        in_specs=[pl.BlockSpec((rows, block_f), lambda j: (0, j))],
        out_specs=pl.BlockSpec((out_rows, block_f), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((out_rows, f), panel.dtype),
        interpret=interpret,
        name="multi_hop_mix",
    )(panel)


# ---------------------------------------------------------------------------
# int8 all-hop megakernel — revisiting grid, per-hop requantization
# ---------------------------------------------------------------------------


def _mhmq_kernel(q_ref, s_ref, state_ref, mx_ref, sc_ref, *, hops: int,
                 w_self: float, w_side: float):
    """Stages over ``program_id(0)``: stage 0 dequantizes the wire payload
    and runs hop 0; each later hop is a (max-accumulate, requantize +
    combine) stage pair.  The f32 evolving panel lives in ``state_ref``
    (the output, revisited every stage); the caller slices the center rows.
    """
    p = pl.program_id(0)
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    del hops  # schedule length is encoded in the grid

    @pl.when(p == 0)
    def _hop0():
        state_ref[...] = _hop_dq(q_ref[...].astype(jnp.float32),
                                 s_ref[...].astype(jnp.float32),
                                 w_self, w_side)

    @pl.when(p % 2 == 1)
    def _row_max():
        @pl.when(j == 0)
        def _reset():
            mx_ref[...] = jnp.zeros_like(mx_ref)

        m = jnp.max(jnp.abs(state_ref[...]), axis=1, keepdims=True)
        mx_ref[...] = jnp.maximum(mx_ref[...],
                                  jnp.broadcast_to(m, mx_ref.shape))

        @pl.when(j == nj - 1)
        def _finalize_scale():
            sc_ref[...] = jnp.maximum(mx_ref[...] / 127.0, _EPS)

    @pl.when((p >= 2) & (p % 2 == 0))
    def _requant_combine():
        scale = sc_ref[...][:, :1]                       # (rows, 1)
        # rounded values are integers, exact in f32 — no int8 cast needed
        q = jnp.clip(jnp.round(state_ref[...] / scale), -127.0, 127.0)
        state_ref[...] = _hop_dq(q, scale, w_self, w_side)


@functools.partial(jax.jit, static_argnames=("hops", "w_self", "w_side",
                                             "block_f", "interpret"))
def multi_hop_mix_quant_flat(q_panel: Array, s_panel: Array, *, hops: int,
                             w_self: float, w_side: float,
                             block_f: int = DEFAULT_BLOCK_F,
                             interpret: bool = False) -> Array:
    """All-hop compressed schedule on an int8 ``(rows, F)`` halo panel with
    per-row f32 scales ``(rows, 1)``.  Returns the full f32 ``(rows, F)``
    evolved panel (callers slice the center rows) — the panel is the
    kernel's cross-stage state, so it is the natural output shape."""
    rows, f = q_panel.shape
    block_f = min(block_f, f)
    if f % block_f:
        raise ValueError(f"multi_hop_mix_quant_flat: F={f} not a multiple "
                         f"of block_f={block_f}; pad the lane tail "
                         f"(ops.multi_hop_mix_quant does)")
    kernel = functools.partial(_mhmq_kernel, hops=hops, w_self=w_self,
                               w_side=w_side)
    q_spec = pl.BlockSpec((rows, block_f), lambda p, j: (0, j))
    s_spec = pl.BlockSpec((rows, 1), lambda p, j: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(2 * hops - 1, f // block_f),
        in_specs=[q_spec, s_spec],
        out_specs=pl.BlockSpec((rows, block_f), lambda p, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((rows, f), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((rows, 128), jnp.float32),   # per-row |z| max acc
            pltpu.VMEM((rows, 128), jnp.float32),   # finalized scales
        ],
        interpret=interpret,
        name="multi_hop_mix_quant",
    )(q_panel, s_panel)
