"""jit'd public wrappers for the Pallas kernels, with backend dispatch.

Dispatch policy (per-call overridable with ``impl=``):

  * ``tpu`` backend            -> Pallas kernel (compiled)
  * anything else (CPU here)   -> pure-jnp oracle from ``ref.py`` — identical
    semantics and matching FLOP structure, so the dry-run's cost_analysis is
    representative.
  * ``impl="pallas_interpret"``-> Pallas kernel body interpreted in Python
    (the CPU validation path used by the kernel tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import quant_mix as _qm
from repro.kernels import ref
from repro.kernels import retract as _rt
from repro.kernels import ring_mix as _rm
from repro.kernels import stiefel_project as _sp
from repro.obs import estimates as _est

Array = jax.Array


def _default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _itemsize(x: Array) -> int:
    return jnp.dtype(x.dtype).itemsize


# ---------------------------------------------------------------------------
# flash attention — public layout (B, S, H, hd) to match the model code
# ---------------------------------------------------------------------------


def _pad_to(x: Array, axis: int, mult: int, value=0) -> tuple[Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    q_positions: Array | None = None,
                    kv_positions: Array | None = None,
                    softmax_scale: float | None = None,
                    impl: str | None = None,
                    block_q: int = _fa.DEFAULT_BLOCK_Q,
                    block_kv: int = _fa.DEFAULT_BLOCK_KV) -> Array:
    """Attention over (B, S, H, hd) q and (B, T, Hkv, hd) k/v."""
    impl = impl or _default_impl()
    _est.record("flash_attention", _est.flash_attention_est(
        q.shape[0], q.shape[1], k.shape[1], q.shape[2], q.shape[3],
        causal=causal, window=window, block_q=block_q,
        itemsize=_itemsize(q)))
    if impl == "ref":
        return ref.blockwise_attention(
            q, k, v, causal=causal, window=window, q_positions=q_positions,
            kv_positions=kv_positions, softmax_scale=softmax_scale)
    if impl == "ref_naive":
        return ref.attention_naive(
            q, k, v, causal=causal, window=window, q_positions=q_positions,
            kv_positions=kv_positions, softmax_scale=softmax_scale)

    b, s, h, hd = q.shape
    t = k.shape[1]
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    qt = jnp.swapaxes(q, 1, 2)           # (B, H, S, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qt, pad_q = _pad_to(qt, 2, min(block_q, max(s, 1)))
    kt, pad_kv = _pad_to(kt, 2, min(block_kv, max(t, 1)))
    vt, _ = _pad_to(vt, 2, min(block_kv, max(t, 1)))
    qp = jnp.pad(q_positions.astype(jnp.int32), ((0, 0), (0, qt.shape[2] - s)),
                 constant_values=0)
    kp = jnp.pad(kv_positions.astype(jnp.int32), ((0, 0), (0, kt.shape[2] - t)),
                 constant_values=-1)

    out = _fa.flash_attention_bhsd(
        qt, kt, vt, qp, kp, causal=causal, window=window,
        softmax_scale=softmax_scale,
        block_q=min(block_q, qt.shape[2]), block_kv=min(block_kv, kt.shape[2]),
        interpret=(impl == "pallas_interpret"))
    out = jnp.swapaxes(out, 1, 2)
    return out[:, :s]


# ---------------------------------------------------------------------------
# stiefel tangent projection
# ---------------------------------------------------------------------------


def stiefel_project(x: Array, g: Array, *, impl: str | None = None,
                    block_d: int = _sp.DEFAULT_BLOCK_D) -> Array:
    """P_{T_x}(g) over the last two dims; leading dims are vmapped."""
    impl = impl or _default_impl()
    d, r = x.shape[-2:]
    _est.record("stiefel_project", _est.stiefel_project_est(
        d, r, lead=max(1, x.size // (d * r)), itemsize=_itemsize(x)))
    if impl == "ref":
        return ref.stiefel_project_ref(x, g)

    interpret = impl == "pallas_interpret"

    def one(xi: Array, gi: Array) -> Array:
        d, r = xi.shape
        # pad r to the 128-lane boundary, d to a multiple of the block size
        pr = (-r) % 128
        pd = (-d) % 128
        d_p = d + pd
        block = block_d if d_p % block_d == 0 else 128
        xi_p = jnp.pad(xi, ((0, pd), (0, pr)))
        gi_p = jnp.pad(gi, ((0, pd), (0, pr)))
        out = _sp.stiefel_project_2d(xi_p, gi_p, block_d=min(block, d_p),
                                     interpret=interpret)
        return out[:d, :r]

    if x.ndim == 2:
        return one(x, g)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    gf = g.reshape((-1,) + g.shape[-2:])
    out = jax.vmap(one)(xf, gf)
    return out.reshape(lead + x.shape[-2:])


# ---------------------------------------------------------------------------
# fused polar retraction
# ---------------------------------------------------------------------------


def fused_retract(x: Array, g: Array, *, ns_iters: int = _rt.DEFAULT_NS_ITERS,
                  impl: str | None = None,
                  block_d: int = _rt.DEFAULT_BLOCK_D) -> Array:
    """R_x(P_{T_x}(g)) over the last two dims; leading dims (the node-stacked
    axis) are vmapped.  ``g`` is the AMBIENT update direction — tangent
    projection happens inside the kernel (GDAHyper.retraction="polar_fused").
    """
    impl = impl or _default_impl()
    d, r = x.shape[-2:]
    _est.record("fused_retract", _est.fused_retract_est(
        d, r, ns_iters=ns_iters, lead=max(1, x.size // (d * r)),
        itemsize=_itemsize(x)))
    if impl == "ref":
        return ref.fused_retract_ref(x, g, ns_iters=ns_iters)

    interpret = impl == "pallas_interpret"

    def one(xi: Array, gi: Array) -> Array:
        d, r = xi.shape
        # pad r to the 128-lane boundary, d to a multiple of the block size;
        # zero padding is exact (see kernels/retract.py docstring)
        pr = (-r) % 128
        pd = (-d) % 128
        d_p = d + pd
        block = block_d if d_p % block_d == 0 else 128
        xi_p = jnp.pad(xi, ((0, pd), (0, pr)))
        gi_p = jnp.pad(gi, ((0, pd), (0, pr)))
        out = _rt.fused_retract_2d(xi_p, gi_p, block_d=min(block, d_p),
                                   ns_iters=ns_iters, interpret=interpret)
        return out[:d, :r]

    if x.ndim == 2:
        return one(x, g)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    gf = g.reshape((-1,) + g.shape[-2:])
    out = jax.vmap(one)(xf, gf)
    return out.reshape(lead + x.shape[-2:])


# ---------------------------------------------------------------------------
# ring mix
# ---------------------------------------------------------------------------


def ring_mix(x_self: Array, x_left: Array, x_right: Array, *,
             w_self: float, w_side: float, impl: str | None = None) -> Array:
    """Local gossip combine for arbitrary leaf sizes.

    Data is flattened to (rows, LANE) VMEM panels; BOTH the lane tail and
    the row tail are zero-padded (and sliced back) so the kernel's
    ``rows % block_rows == 0`` tiling contract always holds — a prime-sized
    leaf no longer degenerates to block_rows=1 (or trips the assert), it
    costs at most 7 padded rows.
    """
    impl = impl or _default_impl()
    _est.record("ring_mix",
                _est.ring_mix_est(x_self.size, itemsize=_itemsize(x_self)))
    if impl == "ref":
        return ref.ring_mix_ref(x_self, x_left, x_right, w_self, w_side)

    shape = x_self.shape
    n = x_self.size
    lane = _rm.LANE
    pad = (-n) % lane
    rows = (n + pad) // lane
    # pad rows to the 8-sublane boundary, then pick the largest block that
    # tiles the padded panel exactly
    pad_rows = (-rows) % 8
    rows_p = rows + pad_rows
    block = rows_p
    for cand in (_rm.DEFAULT_BLOCK_ROWS, 128, 64, 32, 16, 8):
        if rows_p % cand == 0:
            block = cand
            break

    def flat(a):
        af = a.reshape(-1)
        if pad:
            af = jnp.pad(af, (0, pad))
        af = af.reshape(-1, lane)
        if pad_rows:
            af = jnp.pad(af, ((0, pad_rows), (0, 0)))
        return af

    out = _rm.ring_mix_flat(flat(x_self), flat(x_left), flat(x_right),
                            w_self=w_self, w_side=w_side, block_rows=block,
                            interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# fused dequantize + ring combine (compressed gossip hop)
# ---------------------------------------------------------------------------


def quant_mix(q_self: Array, q_left: Array, q_right: Array,
              s_self: Array, s_left: Array, s_right: Array, *,
              w_self: float, w_side: float, out_dtype=jnp.float32,
              impl: str | None = None) -> Array:
    """Combine three int8 payloads with per-row scales in one pass:
    ``wc * dq(qc) + ws * (dq(ql) + dq(qr))``.

    ``q_*``: int8, shape (rows, ...) — trailing dims are flattened.
    ``s_*``: one f32 scale per row; any shape reshapeable to (rows, 1).
    """
    impl = impl or _default_impl()
    rows = q_self.shape[0]
    _est.record("quant_mix", _est.quant_mix_est(
        rows, q_self.size // rows,
        out_itemsize=jnp.dtype(out_dtype).itemsize))
    scales = [s.reshape(rows, 1) for s in (s_self, s_left, s_right)]
    if impl == "ref":
        out = ref.quant_mix_ref(
            q_self.reshape(rows, -1), q_left.reshape(rows, -1),
            q_right.reshape(rows, -1), *scales,
            w_self=w_self, w_side=w_side, out_dtype=out_dtype)
        return out.reshape(q_self.shape)

    cols = q_self.size // rows
    pad_c = (-cols) % 128
    cols_p = cols + pad_c
    # int8 min tile is (32, 128): pad rows up to the sublane boundary so the
    # compiled kernel tiles cleanly (padded rows carry q=0 -> contribute 0)
    pad_r = (-rows) % 32
    rows_p = rows + pad_r

    def flat(q):
        qf = q.reshape(rows, -1)
        return jnp.pad(qf, ((0, pad_r), (0, pad_c)))

    scales = [jnp.pad(s, ((0, pad_r), (0, 0))) for s in scales]
    block_c = cols_p
    for cand in (_qm.DEFAULT_BLOCK_COLS, 1024, 512, 256, 128):
        if cols_p % cand == 0:
            block_c = cand
            break
    out = _qm.quant_mix_2d(flat(q_self), flat(q_left), flat(q_right), *scales,
                           w_self=w_self, w_side=w_side, out_dtype=out_dtype,
                           block_rows=32, block_cols=block_c,
                           interpret=(impl == "pallas_interpret"))
    return out[:rows, :cols].reshape(q_self.shape)
