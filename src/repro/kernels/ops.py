"""jit'd public wrappers for the Pallas kernels, with backend dispatch.

Dispatch policy (per-call overridable with ``impl=``, process-wide with
``REPRO_KERNEL_IMPL=ref|pallas|pallas_interpret``):

  * ``tpu`` backend            -> Pallas kernel (compiled)
  * anything else (CPU here)   -> pure-jnp oracle from ``ref.py`` — identical
    semantics and matching FLOP structure, so the dry-run's cost_analysis is
    representative.
  * ``impl="pallas_interpret"``-> Pallas kernel body interpreted in Python
    (the CPU validation path used by the kernel tests).

Launch configs (block shapes, NS iteration counts) resolve through
``kernels/tune.py``: a tuned config cached for this exact
(kernel, shape, dtype) key wins, the hand-picked defaults otherwise
(``REPRO_TUNE=off`` skips the cache entirely).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import multi_hop_mix as _mh
from repro.kernels import paged_decode as _pd
from repro.kernels import quant_mix as _qm
from repro.kernels import ref
from repro.kernels import retract as _rt
from repro.kernels import ring_mix as _rm
from repro.kernels import stiefel_project as _sp
from repro.kernels import tune as _tune
from repro.obs import estimates as _est

Array = jax.Array


def _default_impl() -> str:
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _itemsize(x: Array) -> int:
    return jnp.dtype(x.dtype).itemsize


# ---------------------------------------------------------------------------
# flash attention — public layout (B, S, H, hd) to match the model code
# ---------------------------------------------------------------------------


def _pad_to(x: Array, axis: int, mult: int, value=0) -> tuple[Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    q_positions: Array | None = None,
                    kv_positions: Array | None = None,
                    softmax_scale: float | None = None,
                    impl: str | None = None,
                    block_q: int | None = None,
                    block_kv: int | None = None) -> Array:
    """Attention over (B, S, H, hd) q and (B, T, Hkv, hd) k/v.

    ``block_q`` / ``block_kv`` default to the tuned config for this
    (B, S, T, H, hd, dtype) key when one is cached (see ``kernels/tune.py``;
    on the ref path the tuned ``block_kv`` drives the streaming chunk), else
    the hand-picked module defaults; explicit values always win.
    """
    impl = impl or _default_impl()
    tuned = {}
    if block_q is None or block_kv is None:
        tuned = _tune.lookup(
            "flash_attention",
            (q.shape[0], q.shape[1], k.shape[1], q.shape[2], q.shape[3]),
            str(q.dtype)) or {}
    if block_q is None:
        block_q = tuned.get("block_q", _fa.DEFAULT_BLOCK_Q)
    if block_kv is None:
        block_kv = tuned.get("block_kv")          # None => ref default chunk
    _est.record("flash_attention", _est.flash_attention_est(
        q.shape[0], q.shape[1], k.shape[1], q.shape[2], q.shape[3],
        causal=causal, window=window, block_q=block_q,
        itemsize=_itemsize(q)))
    if impl == "ref":
        kw = {} if block_kv is None else {"chunk": block_kv}
        return ref.blockwise_attention(
            q, k, v, causal=causal, window=window, q_positions=q_positions,
            kv_positions=kv_positions, softmax_scale=softmax_scale, **kw)
    if block_kv is None:
        block_kv = _fa.DEFAULT_BLOCK_KV
    if impl == "ref_naive":
        return ref.attention_naive(
            q, k, v, causal=causal, window=window, q_positions=q_positions,
            kv_positions=kv_positions, softmax_scale=softmax_scale)

    b, s, h, hd = q.shape
    t = k.shape[1]
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    qt = jnp.swapaxes(q, 1, 2)           # (B, H, S, hd)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    qt, pad_q = _pad_to(qt, 2, min(block_q, max(s, 1)))
    kt, pad_kv = _pad_to(kt, 2, min(block_kv, max(t, 1)))
    vt, _ = _pad_to(vt, 2, min(block_kv, max(t, 1)))
    qp = jnp.pad(q_positions.astype(jnp.int32), ((0, 0), (0, qt.shape[2] - s)),
                 constant_values=0)
    kp = jnp.pad(kv_positions.astype(jnp.int32), ((0, 0), (0, kt.shape[2] - t)),
                 constant_values=-1)

    out = _fa.flash_attention_bhsd(
        qt, kt, vt, qp, kp, causal=causal, window=window,
        softmax_scale=softmax_scale,
        block_q=min(block_q, qt.shape[2]), block_kv=min(block_kv, kt.shape[2]),
        interpret=(impl == "pallas_interpret"))
    out = jnp.swapaxes(out, 1, 2)
    return out[:, :s]


# ---------------------------------------------------------------------------
# paged-decode attention — the serving path's block-table gather kernel
# ---------------------------------------------------------------------------


def paged_decode_attention(q: Array, k_pages: Array, v_pages: Array,
                           block_table: Array, seq_lens: Array, *,
                           window: int | None = None,
                           softmax_scale: float | None = None,
                           impl: str | None = None,
                           pages_per_block: int | None = None) -> Array:
    """One decode step for S slots over a paged KV pool.

    q (S, H, hd); pools (P, page_size, Hkv, hd/hdv); block_table (S, M)
    int32 (-1 = unallocated); seq_lens (S,) int32 (valid tokens, the query
    sits at ``seq_lens - 1``).  Returns (S, H, hdv).

    ``pages_per_block`` (pages fused per kernel grid step) defaults to the
    tuned config for this (S, M, page_size, hd, dtype) key when one is
    cached, else 1; the block table is padded with -1 columns so the knob
    always tiles.
    """
    impl = impl or _default_impl()
    s_slots, h, hd = q.shape
    ps, hkv = k_pages.shape[1], k_pages.shape[2]
    m_pages = block_table.shape[1]
    _est.record("paged_decode", _est.paged_decode_est(
        s_slots, h, hkv, hd, m_pages, ps, itemsize=_itemsize(q)))
    if impl in ("ref", "ref_naive"):
        return ref.paged_decode_attention_ref(
            q, k_pages, v_pages, block_table, seq_lens, window=window,
            softmax_scale=softmax_scale)

    if pages_per_block is None:
        tuned = _tune.lookup("paged_decode", (s_slots, m_pages, ps, hd),
                             str(q.dtype)) or {}
        pages_per_block = tuned.get("pages_per_block",
                                    _pd.DEFAULT_PAGES_PER_BLOCK)
    bt, _ = _pad_to(block_table, 1, max(pages_per_block, 1), value=-1)
    group = h // hkv
    qg = q.reshape(s_slots, hkv, group, hd)
    out = _pd.paged_decode_shgd(
        qg, k_pages, v_pages, bt, seq_lens, window=window,
        softmax_scale=softmax_scale, pages_per_block=pages_per_block,
        interpret=(impl == "pallas_interpret"))
    return out.reshape(s_slots, h, v_pages.shape[-1])


# ---------------------------------------------------------------------------
# stiefel tangent projection
# ---------------------------------------------------------------------------


def stiefel_project(x: Array, g: Array, *, impl: str | None = None,
                    block_d: int = _sp.DEFAULT_BLOCK_D) -> Array:
    """P_{T_x}(g) over the last two dims; leading dims are vmapped."""
    impl = impl or _default_impl()
    d, r = x.shape[-2:]
    _est.record("stiefel_project", _est.stiefel_project_est(
        d, r, lead=max(1, x.size // (d * r)), itemsize=_itemsize(x)))
    if impl == "ref":
        return ref.stiefel_project_ref(x, g)

    interpret = impl == "pallas_interpret"

    def one(xi: Array, gi: Array) -> Array:
        d, r = xi.shape
        # pad r to the 128-lane boundary, d to a multiple of the block size
        pr = (-r) % 128
        pd = (-d) % 128
        d_p = d + pd
        block = block_d if d_p % block_d == 0 else 128
        xi_p = jnp.pad(xi, ((0, pd), (0, pr)))
        gi_p = jnp.pad(gi, ((0, pd), (0, pr)))
        out = _sp.stiefel_project_2d(xi_p, gi_p, block_d=min(block, d_p),
                                     interpret=interpret)
        return out[:d, :r]

    if x.ndim == 2:
        return one(x, g)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    gf = g.reshape((-1,) + g.shape[-2:])
    out = jax.vmap(one)(xf, gf)
    return out.reshape(lead + x.shape[-2:])


# ---------------------------------------------------------------------------
# fused polar retraction
# ---------------------------------------------------------------------------


def fused_retract(x: Array, g: Array, *, ns_iters: int | None = None,
                  impl: str | None = None,
                  block_d: int | None = None) -> Array:
    """R_x(P_{T_x}(g)) over the last two dims; leading dims (the node-stacked
    axis) are vmapped.  ``g`` is the AMBIENT update direction — tangent
    projection happens inside the kernel (GDAHyper.retraction="polar_fused").

    ``ns_iters`` / ``block_d`` default to the tuned config for this
    (d, r, dtype) when one is cached (see ``kernels/tune.py``), else the
    hand-picked defaults; explicit values always win.
    """
    impl = impl or _default_impl()
    d, r = x.shape[-2:]
    if ns_iters is None or block_d is None:
        cfg = _tune.lookup("fused_retract", (d, r), str(x.dtype)) or {}
        if ns_iters is None:
            ns_iters = cfg.get("ns_iters", _rt.DEFAULT_NS_ITERS)
        if block_d is None:
            block_d = cfg.get("block_d", _rt.DEFAULT_BLOCK_D)
    _est.record("fused_retract", _est.fused_retract_est(
        d, r, ns_iters=ns_iters, lead=max(1, x.size // (d * r)),
        itemsize=_itemsize(x)))
    if impl == "ref":
        return ref.fused_retract_ref(x, g, ns_iters=ns_iters)

    interpret = impl == "pallas_interpret"

    def one(xi: Array, gi: Array) -> Array:
        d, r = xi.shape
        # pad r to the 128-lane boundary, d to a multiple of the block size;
        # zero padding is exact (see kernels/retract.py docstring)
        pr = (-r) % 128
        pd = (-d) % 128
        d_p = d + pd
        block = block_d if d_p % block_d == 0 else 128
        xi_p = jnp.pad(xi, ((0, pd), (0, pr)))
        gi_p = jnp.pad(gi, ((0, pd), (0, pr)))
        out = _rt.fused_retract_2d(xi_p, gi_p, block_d=min(block, d_p),
                                   ns_iters=ns_iters, interpret=interpret)
        return out[:d, :r]

    if x.ndim == 2:
        return one(x, g)
    lead = x.shape[:-2]
    xf = x.reshape((-1,) + x.shape[-2:])
    gf = g.reshape((-1,) + g.shape[-2:])
    out = jax.vmap(one)(xf, gf)
    return out.reshape(lead + x.shape[-2:])


# ---------------------------------------------------------------------------
# ring mix
# ---------------------------------------------------------------------------


def ring_mix(x_self: Array, x_left: Array, x_right: Array, *,
             w_self: float, w_side: float, impl: str | None = None) -> Array:
    """Local gossip combine for arbitrary leaf sizes.

    Data is flattened to (rows, LANE) VMEM panels; BOTH the lane tail and
    the row tail are zero-padded (and sliced back) so the kernel's
    ``rows % block_rows == 0`` tiling contract always holds — a prime-sized
    leaf no longer degenerates to block_rows=1 (or trips the assert), it
    costs at most 7 padded rows.
    """
    impl = impl or _default_impl()
    _est.record("ring_mix",
                _est.ring_mix_est(x_self.size, itemsize=_itemsize(x_self)))
    if impl == "ref":
        return ref.ring_mix_ref(x_self, x_left, x_right, w_self, w_side)

    shape = x_self.shape
    n = x_self.size
    lane = _rm.LANE
    pad = (-n) % lane
    rows = (n + pad) // lane
    # pad rows to the 8-sublane boundary, then pick the largest block that
    # tiles the padded panel exactly
    pad_rows = (-rows) % 8
    rows_p = rows + pad_rows
    tuned = _tune.lookup("ring_mix", (rows_p, lane), str(x_self.dtype)) or {}
    cands = ([tuned["block_rows"]] if "block_rows" in tuned else []) \
        + [_rm.DEFAULT_BLOCK_ROWS, 128, 64, 32, 16, 8]
    block = rows_p
    for cand in cands:
        if rows_p % cand == 0:
            block = cand
            break

    def flat(a):
        af = a.reshape(-1)
        if pad:
            af = jnp.pad(af, (0, pad))
        af = af.reshape(-1, lane)
        if pad_rows:
            af = jnp.pad(af, ((0, pad_rows), (0, 0)))
        return af

    out = _rm.ring_mix_flat(flat(x_self), flat(x_left), flat(x_right),
                            w_self=w_self, w_side=w_side, block_rows=block,
                            interpret=(impl == "pallas_interpret"))
    return out.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# fused dequantize + ring combine (compressed gossip hop)
# ---------------------------------------------------------------------------


def quant_mix(q_self: Array, q_left: Array, q_right: Array,
              s_self: Array, s_left: Array, s_right: Array, *,
              w_self: float, w_side: float, out_dtype=jnp.float32,
              impl: str | None = None) -> Array:
    """Combine three int8 payloads with per-row scales in one pass:
    ``wc * dq(qc) + ws * (dq(ql) + dq(qr))``.

    ``q_*``: int8, shape (rows, ...) — trailing dims are flattened.
    ``s_*``: one f32 scale per row; any shape reshapeable to (rows, 1).
    """
    impl = impl or _default_impl()
    rows = q_self.shape[0]
    _est.record("quant_mix", _est.quant_mix_est(
        rows, q_self.size // rows,
        out_itemsize=jnp.dtype(out_dtype).itemsize))
    scales = [s.reshape(rows, 1) for s in (s_self, s_left, s_right)]
    if impl == "ref":
        out = ref.quant_mix_ref(
            q_self.reshape(rows, -1), q_left.reshape(rows, -1),
            q_right.reshape(rows, -1), *scales,
            w_self=w_self, w_side=w_side, out_dtype=out_dtype)
        return out.reshape(q_self.shape)

    cols = q_self.size // rows
    pad_c = (-cols) % 128
    cols_p = cols + pad_c
    # int8 min tile is (32, 128): pad rows up to the sublane boundary so the
    # compiled kernel tiles cleanly (padded rows carry q=0 -> contribute 0)
    pad_r = (-rows) % 32
    rows_p = rows + pad_r

    def flat(q):
        qf = q.reshape(rows, -1)
        return jnp.pad(qf, ((0, pad_r), (0, pad_c)))

    scales = [jnp.pad(s, ((0, pad_r), (0, 0))) for s in scales]
    tuned = _tune.lookup("quant_mix", (rows_p, cols_p), "int8") or {}
    cands = ([tuned["block_cols"]] if "block_cols" in tuned else []) \
        + [_qm.DEFAULT_BLOCK_COLS, 1024, 512, 256, 128]
    block_c = cols_p
    for cand in cands:
        if cols_p % cand == 0:
            block_c = cand
            break
    out = _qm.quant_mix_2d(flat(q_self), flat(q_left), flat(q_right), *scales,
                           w_self=w_self, w_side=w_side, out_dtype=out_dtype,
                           block_rows=32, block_cols=block_c,
                           interpret=(impl == "pallas_interpret"))
    return out[:rows, :cols].reshape(q_self.shape)


# ---------------------------------------------------------------------------
# fused multi-hop ring mix (halo-panel megakernel)
# ---------------------------------------------------------------------------


def _pick_block_f(kernel: str, rows_p: int, f_p: int, dtype,
                  hops: int, block_f: int | None) -> int:
    """Feature-block width: explicit > tuned-for-this-key > the largest
    default candidate dividing the padded lane count (which is a multiple
    of 128, so the 128 fallback always divides)."""
    if block_f is not None:
        return block_f
    tuned = _tune.lookup(kernel, (rows_p, f_p), str(dtype),
                         extra={"hops": hops}) or {}
    cands = ([tuned["block_f"]] if "block_f" in tuned else []) \
        + [_mh.DEFAULT_BLOCK_F, 4096, 2048, 512, 256, 128]
    for cand in cands:
        if f_p % cand == 0:
            return cand
    return f_p


def multi_hop_mix(panel: Array, *, hops: int, out_rows: int, halo: int,
                  w_self: float, w_side: float, impl: str | None = None,
                  block_f: int | None = None) -> Array:
    """``hops`` fused ring combines on a halo panel ``(halo + b + halo, ...)``
    (trailing dims flattened); returns the exact ``(out_rows, ...)`` center
    rows.  Requires ``halo >= hops``; both the lane tail and (for the
    compiled kernel) the row tail are zero-padded — bottom-row padding is
    exact because panel-end garbage advances one row per hop and never
    reaches the center rows.
    """
    assert halo >= hops, (halo, hops)
    impl = impl or _default_impl()
    rows = panel.shape[0]
    f = panel.size // rows
    _est.record("multi_hop_mix", _est.multi_hop_mix_est(
        rows, f, hops=hops, out_rows=out_rows, itemsize=_itemsize(panel)))
    if impl == "ref":
        out = ref.multi_hop_mix_ref(panel.reshape(rows, -1), hops=hops,
                                    out_rows=out_rows, halo=halo,
                                    w_self=w_self, w_side=w_side)
        return out.reshape((out_rows,) + panel.shape[1:])

    pad_f = (-f) % 128
    pad_r = (-rows) % 8
    p2 = jnp.pad(panel.reshape(rows, -1), ((0, pad_r), (0, pad_f)))
    f_p = f + pad_f
    block = _pick_block_f("multi_hop_mix", rows + pad_r, f_p, panel.dtype,
                          hops, block_f)
    out = _mh.multi_hop_mix_flat(p2, hops=hops, out_rows=out_rows, halo=halo,
                                 w_self=w_self, w_side=w_side, block_f=block,
                                 interpret=(impl == "pallas_interpret"))
    return out[:, :f].reshape((out_rows,) + panel.shape[1:])


def multi_hop_mix_quant(q_panel: Array, s_panel: Array, *, hops: int,
                        out_rows: int, halo: int, w_self: float,
                        w_side: float, out_dtype=jnp.float32,
                        impl: str | None = None,
                        block_f: int | None = None) -> Array:
    """All-hop compressed ``hops``-hop schedule on an int8 halo panel with
    per-row f32 scales: hop 0 fuses dequantize + combine, later hops
    requantize deterministically before combining (the values a receiver
    decodes from an int8 wire).  Returns the ``(out_rows, ...)`` center
    rows in ``out_dtype``."""
    assert halo >= hops, (halo, hops)
    impl = impl or _default_impl()
    rows = q_panel.shape[0]
    f = q_panel.size // rows
    _est.record("multi_hop_mix_quant", _est.multi_hop_mix_est(
        rows, f, hops=hops, out_rows=out_rows, quant=True))
    s2 = s_panel.reshape(rows, 1)
    if impl == "ref":
        z = ref.multi_hop_mix_quant_ref(q_panel.reshape(rows, -1), s2,
                                        hops=hops, w_self=w_self,
                                        w_side=w_side)
        return z[halo:halo + out_rows].astype(out_dtype) \
            .reshape((out_rows,) + q_panel.shape[1:])

    # int8 min tile is (32, 128); padded q rows are zero -> dequantize to 0
    pad_f = (-f) % 128
    pad_r = (-rows) % 32
    q2 = jnp.pad(q_panel.reshape(rows, -1), ((0, pad_r), (0, pad_f)))
    s2 = jnp.pad(s2, ((0, pad_r), (0, 0)), constant_values=1.0)
    f_p = f + pad_f
    block = _pick_block_f("multi_hop_mix_quant", rows + pad_r, f_p, "int8",
                          hops, block_f)
    z = _mh.multi_hop_mix_quant_flat(q2, s2, hops=hops, w_self=w_self,
                                     w_side=w_side, block_f=block,
                                     interpret=(impl == "pallas_interpret"))
    return z[halo:halo + out_rows, :f].astype(out_dtype) \
        .reshape((out_rows,) + q_panel.shape[1:])
