"""Fused dequantize + weighted 3-way combine Pallas kernel.

The compressed-gossip hop receives its two ring neighbours' payloads as int8
panels with one float32 scale per row (row = node / node-shard).  The naive
pipeline dequantizes three buffers to f32 in HBM and then runs the
``ring_mix`` combine — 4 streamed arrays where one suffices.  This kernel
fuses both:

    out[i, :] = w_self * s_c[i] * qc[i, :]
              + w_side * (s_l[i] * ql[i, :] + s_r[i] * qr[i, :])

reading the int8 payloads directly (4x less HBM traffic than pre-dequantized
inputs) and writing the combined f32/bf16 result once.  Like ``ring_mix`` it
is pure-bandwidth elementwise work tiled as (block_rows, lane) VMEM panels;
the int8 min-tile is (32, 128) so the lane width stays a multiple of 128.

``ref.quant_mix_ref`` is the oracle; ``ops.quant_mix`` dispatches and owns
padding/blocking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK_COLS = 2048


def _quant_mix_kernel(qc_ref, ql_ref, qr_ref, sc_ref, sl_ref, sr_ref, o_ref,
                      *, w_self: float, w_side: float):
    def dq(q_ref, s_ref):
        return q_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)

    o_ref[...] = (w_self * dq(qc_ref, sc_ref)
                  + w_side * (dq(ql_ref, sl_ref) + dq(qr_ref, sr_ref))
                  ).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("w_self", "w_side", "out_dtype", "block_rows",
                              "block_cols", "interpret"))
def quant_mix_2d(q_self: Array, q_left: Array, q_right: Array,
                 s_self: Array, s_left: Array, s_right: Array, *,
                 w_self: float, w_side: float, out_dtype=jnp.float32,
                 block_rows: int = 8, block_cols: int = DEFAULT_BLOCK_COLS,
                 interpret: bool = False) -> Array:
    """int8 q_* (rows, cols); f32 s_* (rows, 1) — one scale per row.
    rows % block_rows == 0 and cols % block_cols == 0."""
    rows, cols = q_self.shape
    block_rows = min(block_rows, rows)
    block_cols = min(block_cols, cols)
    assert rows % block_rows == 0 and cols % block_cols == 0
    kernel = functools.partial(_quant_mix_kernel, w_self=w_self, w_side=w_side)
    q_spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    s_spec = pl.BlockSpec((block_rows, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(rows // block_rows, cols // block_cols),
        in_specs=[q_spec, q_spec, q_spec, s_spec, s_spec, s_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), out_dtype),
        interpret=interpret,
        name="quant_mix",
    )(q_self, q_left, q_right, s_self, s_left, s_right)
