"""Fused polar-retraction Pallas kernel.

The DRGDA x-update hot spot, one kernel per Stiefel leaf: given the base
point ``x`` and the AMBIENT update direction ``g`` (the optimizer's
``alpha * [W^k x]_i - beta * u_i``), compute

    u   = P_{T_x}(g) = g - x sym(x^T g)          (tangent projection)
    out = (x + u)(I + u^T u)^{-1/2}              (polar retraction, Lemma 1)

in ONE pallas_call.  The unfused path launches four separate XLA ops
(two Gram matmuls + the Newton--Schulz loop + the apply matmul), each
streaming the tall (d, r) operands through HBM again; here the (r, r)
algebra never leaves VMEM scratch and ``x``/``g`` are read exactly twice.

Key identity — because the algorithm keeps ``x`` exactly on St(d, r)
(x^T x = I), every (r, r) statistic of ``u`` is expressible from two
d-accumulated Grams of the INPUTS:

    B = x^T g,   C = g^T g,   S = sym(B)
    u^T u = C - B^T S - S B + S S
    out   = (x + u) inv = x @ [(I - S) inv] + g @ [inv],
    inv   = (I + u^T u)^{-1/2}   (Newton--Schulz, in-kernel)

so the kernel is a two-pass revisiting grid over d-blocks:

  pass 0  accumulate B, C into VMEM scratch; on the last block run the
          (r, r) finalization: S, A = I + u^T u, the coupled NS iteration,
          and the two apply matrices M1 = (I - S) inv, M2 = inv.
  pass 1  stream the same d-blocks again: out_block = x_blk @ M1 + g_blk @ M2.

``r`` is padded to the 128-lane boundary by the ops.py wrapper; zero
padding is exact end to end (padded A is the identity block, whose NS
inverse sqrt is itself, and padded output rows/cols come out zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_D = 256
DEFAULT_NS_ITERS = 20


def _ns_invsqrt(a: Array, iters: int) -> Array:
    """Coupled Newton--Schulz inverse sqrt on an (r, r) VMEM value — the
    same iteration as geometry.stiefel._invsqrt_newton_schulz."""
    r = a.shape[-1]
    eye = jnp.eye(r, dtype=a.dtype)
    c = jnp.max(jnp.sum(jnp.abs(a), axis=-1), axis=-1)[..., None, None] + 1e-6
    y = a / c
    z = jnp.broadcast_to(eye, a.shape)

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * eye - jnp.dot(z, y, preferred_element_type=jnp.float32))
        return (jnp.dot(y, t, preferred_element_type=jnp.float32),
                jnp.dot(t, z, preferred_element_type=jnp.float32))

    _, z = jax.lax.fori_loop(0, iters, body, (y, z))
    return z * jax.lax.rsqrt(c)


def _fused_kernel(x_ref, g_ref, o_ref, b_acc, c_acc, m1_ref, m2_ref, *,
                  ns_iters: int):
    p = pl.program_id(0)      # pass: 0 = accumulate/finalize, 1 = apply
    i = pl.program_id(1)      # d-block
    r = b_acc.shape[-1]

    @pl.when((p == 0) & (i == 0))
    def _init():
        b_acc[...] = jnp.zeros_like(b_acc)
        c_acc[...] = jnp.zeros_like(c_acc)

    @pl.when(p == 0)
    def _accumulate():
        x = x_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        b_acc[...] += jax.lax.dot_general(
            x, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        c_acc[...] += jax.lax.dot_general(
            g, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        @pl.when(i == pl.num_programs(1) - 1)
        def _finalize():
            eye = jnp.eye(r, dtype=jnp.float32)
            b = b_acc[...]
            c = c_acc[...]
            s = 0.5 * (b + b.T)
            # u^T u = C - B^T S - S B + S S   (uses x^T x = I)
            bts = jnp.dot(b.T, s, preferred_element_type=jnp.float32)
            utu = c - bts - bts.T \
                + jnp.dot(s, s, preferred_element_type=jnp.float32)
            inv = _ns_invsqrt(eye + utu, ns_iters)
            m2_ref[...] = inv
            m1_ref[...] = jnp.dot(eye - s, inv,
                                  preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _apply():
        x = x_ref[...].astype(jnp.float32)
        g = g_ref[...].astype(jnp.float32)
        out = jax.lax.dot_general(
            x, m1_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        out += jax.lax.dot_general(
            g, m2_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "ns_iters",
                                             "interpret"))
def fused_retract_2d(x: Array, g: Array, *, block_d: int = DEFAULT_BLOCK_D,
                     ns_iters: int = DEFAULT_NS_ITERS,
                     interpret: bool = False) -> Array:
    """R_x(P_x(g)) for a single (d, r) pair; d % block_d == 0 (ops.py pads)."""
    d, r = x.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)
    n_d = d // block_d

    spec = pl.BlockSpec((block_d, r), lambda p, i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fused_kernel, ns_iters=ns_iters),
        grid=(2, n_d),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((d, r), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((r, r), jnp.float32),   # B = x^T g accumulator
            pltpu.VMEM((r, r), jnp.float32),   # C = g^T g accumulator
            pltpu.VMEM((r, r), jnp.float32),   # M1 = (I - S) inv
            pltpu.VMEM((r, r), jnp.float32),   # M2 = inv
        ],
        interpret=interpret,
        name="fused_polar_retract",
    )(x, g)
