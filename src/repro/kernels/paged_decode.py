"""Paged-decode flash attention Pallas TPU kernel (block-table gather).

The serving path's KV cache is *paged* (``repro.serve.kv_cache``): each
decode slot owns a row of a block table whose entries index fixed-size
pages ``(page_size, Hkv, hd)`` inside one shared pool.  This kernel runs
one decode step for every slot — q is a single token per slot — attending
over that slot's pages with an online softmax, **gathering pages through
the block table inside the kernel**: the table and the per-slot sequence
lengths ride as scalar-prefetch operands (SMEM), so every k/v BlockSpec
index_map can pick the next physical page while the previous block is
still being computed.

Layout: q ``(S, Hkv, G, hd)`` (S slots, G = n_heads // n_kv_heads query
heads per kv head); pools ``(P, page_size, Hkv, hd)``; block table
``(S, M)`` int32 (-1 = unallocated; reads clamp to page 0, the dump page,
and are fully masked); seq_lens ``(S,)`` int32 — valid tokens including
the current query token at position ``seq_lens - 1``.

Grid: ``(S, Hkv, M // pages_per_block)`` with the page loop innermost —
TPU grid execution is sequential there, so the (acc, m, l) VMEM scratch
persists across page steps exactly like ``flash_attention``'s kv loop.
``pages_per_block`` fuses several page fetches per grid step (the tuned
knob, see ``kernels/tune.py``) by passing the pool once per fused page
with staggered index_maps.

Validated on CPU with ``interpret=True`` against
``ref.paged_decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30

DEFAULT_PAGES_PER_BLOCK = 1


def _paged_kernel(bt_ref, sl_ref, q_ref, *refs, scale: float,
                  window: int | None, n_blocks: int, g_pages: int,
                  page_size: int):
    k_refs = refs[:g_pages]
    v_refs = refs[g_pages:2 * g_pages]
    o_ref = refs[2 * g_pages]
    acc_ref, m_ref, l_ref = refs[2 * g_pages + 1:]
    i = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    sl = sl_ref[i]                                       # valid tokens
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, hd)
    k = jnp.concatenate([r[0, :, 0, :] for r in k_refs], axis=0) \
        .astype(jnp.float32)                             # (g_pages*ps, hd)
    v = jnp.concatenate([r[0, :, 0, :] for r in v_refs], axis=0) \
        .astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, span)

    span = g_pages * page_size
    pos = j * span + jax.lax.broadcasted_iota(jnp.int32, (1, span), 1)
    mask = pos < sl                                      # (1, span)
    if window is not None:
        # the query sits at position sl - 1
        mask &= (sl - 1 - pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # fully-masked spans (empty slots / dump pages): keep rows exactly zero
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softmax_scale", "pages_per_block",
                     "interpret"))
def paged_decode_shgd(q: Array, k_pages: Array, v_pages: Array,
                      block_table: Array, seq_lens: Array, *,
                      window: int | None = None,
                      softmax_scale: float | None = None,
                      pages_per_block: int = DEFAULT_PAGES_PER_BLOCK,
                      interpret: bool = False) -> Array:
    """q: (S, Hkv, G, hd); pools (P, ps, Hkv, hd/hdv); block_table (S, M)
    int32; seq_lens (S,) int32.  Returns (S, Hkv, G, hdv).

    ``M % pages_per_block == 0`` (ops.py pads the table with -1 columns);
    hd should be a multiple of 128 for MXU alignment on real hardware
    (any hd works in interpret mode).
    """
    s_slots, hkv, group, hd = q.shape
    n_pages, ps, _, _ = k_pages.shape
    hdv = v_pages.shape[-1]
    m_pages = block_table.shape[1]
    g = pages_per_block
    assert m_pages % g == 0, (m_pages, g)
    n_blocks = m_pages // g
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    grid = (s_slots, hkv, n_blocks)

    def page_map(off):
        # scalar-prefetch index_map: clamp -1 (unallocated) to the dump
        # page 0 — those positions are >= seq_len and fully masked anyway
        def index(i, kh, j, bt, sl):
            return (jnp.maximum(bt[i, j * g + off], 0), 0, kh, 0)
        return index

    in_specs = [pl.BlockSpec((1, 1, group, hd),
                             lambda i, kh, j, bt, sl: (i, kh, 0, 0))]
    in_specs += [pl.BlockSpec((1, ps, 1, hd), page_map(off))
                 for off in range(g)]
    in_specs += [pl.BlockSpec((1, ps, 1, hdv), page_map(off))
                 for off in range(g)]

    kernel = functools.partial(_paged_kernel, scale=scale, window=window,
                               n_blocks=n_blocks, g_pages=g, page_size=ps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, hdv),
                               lambda i, kh, j, bt, sl: (i, kh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, hdv), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s_slots, hkv, group, hdv), q.dtype),
        interpret=interpret,
        name="paged_decode",
    )(block_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      q, *([k_pages] * g), *([v_pages] * g))
