"""Flash attention Pallas TPU kernel (online softmax, VMEM-tiled).

Target: TPU v5e — block shapes are MXU-aligned (multiples of 128 on the
matmul dims).  Validated on CPU with ``interpret=True`` against
``ref.attention_naive`` / ``ref.blockwise_attention``.

Layout: q (B, H, S, hd); k/v (B, Hkv, T, hd); GQA handled by the k/v
index_map (kv head = q head // group) — KV is never materialized per q-head.
Supports causal masking with absolute positions (decode: S == 1 with a long
cache) and a static sliding window.

Grid: (B, H, n_q_blocks, n_kv_blocks); the kv loop is the innermost grid
dim, with (acc, m, l) carried in VMEM scratch across kv steps (TPU grid
execution is sequential, so scratch persists).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -1e30

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _flash_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  n_kv_blocks: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bkv)

    qp = qpos_ref[0]                                      # (bq,)  int32
    kp = kpos_ref[0]                                      # (bkv,) int32
    mask = (kp >= 0)[None, :]
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                   # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    # fully-masked rows: keep them zero (m stays NEG_INF => exp underflows OK)
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softmax_scale",
                     "block_q", "block_kv", "interpret"))
def flash_attention_bhsd(q: Array, k: Array, v: Array,
                         q_positions: Array, kv_positions: Array, *,
                         causal: bool = True, window: int | None = None,
                         softmax_scale: float | None = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_kv: int = DEFAULT_BLOCK_KV,
                         interpret: bool = False) -> Array:
    """q: (B, H, S, hd); k/v: (B, Hkv, T, hd); positions (B, S)/(B, T).

    S and T must be multiples of the block sizes (ops.py pads); hd should be
    a multiple of 128 for MXU alignment on real hardware (any hd works in
    interpret mode).
    """
    b, h, s_len, hd = q.shape
    hkv, t_len = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    group = h // hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    block_q = min(block_q, s_len)
    block_kv = min(block_kv, t_len)
    n_q = s_len // block_q
    n_kv = t_len // block_kv
    assert s_len % block_q == 0 and t_len % block_kv == 0

    grid = (b, h, n_q, n_kv)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, n_kv_blocks=n_kv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b_, h_, i, j: (b_, i)),
            pl.BlockSpec((1, block_kv), lambda b_, h_, i, j: (b_, j)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, block_kv, hdv),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hdv),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s_len, hdv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hdv), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(q_positions, kv_positions, q, k, v)
