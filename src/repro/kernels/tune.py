"""Autotune-and-cache for the Pallas kernel launch configs.

tinygrad-style measure-or-load (SNIPPETS.md §search): every kernel's launch
config (block shapes, Newton--Schulz iteration count) is either hand-picked
(the ``DEFAULT`` below — what shipped before this module) or *tuned*: a
timed search over the kernel's candidate space, gated on accuracy where the
config changes math (``fused_retract``'s ``ns_iters``), cached as JSON under
``experiments/tune/<device_kind>.json`` keyed on
``kernel|shape|dtype[|extras]``.

``kernels/ops.py`` consults :func:`lookup` at trace time; the env knob is

  ``REPRO_TUNE=off``     — never consult the cache, always ship defaults.
  ``REPRO_TUNE=load``    — (default) use a cached config when one exists
                           for this exact key, defaults otherwise; never
                           measures anything.
  ``REPRO_TUNE=search``  — measure-or-load: a cache miss triggers the
                           search once and persists the result, so the
                           second invocation of the same program is pure
                           load.

Timing runs against whatever this process actually dispatches (the Pallas
kernel on TPU, the jnp oracle elsewhere) — on CPU the block-shape axes are
flat and the 5% hysteresis keeps the default, while ``ns_iters`` changes
real work on every backend, so the cache always demonstrates at least one
non-default tuned config.  Each record carries
``launch/roofline.place()``'s placement of the kernel's analytical
Estimates so the report can position tuned configs on the roofline.

Delete ``experiments/tune/`` (or point ``REPRO_TUNE_DIR`` elsewhere) to
retune from scratch.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Optional

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))

MODES = ("off", "load", "search")

#: hand-picked launch configs (what ops.py shipped before the tuner)
DEFAULTS = {
    "ring_mix": {"block_rows": 256},
    "quant_mix": {"block_cols": 2048},
    "multi_hop_mix": {"block_f": 1024},
    "multi_hop_mix_quant": {"block_f": 1024},
    "fused_retract": {"block_d": 256, "ns_iters": 20},
    "flash_attention": {"block_q": 128, "block_kv": 128},
    "paged_decode": {"pages_per_block": 1},
}

#: candidate spaces (the default is always included and is the fallback)
SPACES = {
    "ring_mix": [{"block_rows": v} for v in (512, 256, 128, 64, 32, 16, 8)],
    "quant_mix": [{"block_cols": v}
                  for v in (4096, 2048, 1024, 512, 256, 128)],
    "multi_hop_mix": [{"block_f": v}
                      for v in (4096, 2048, 1024, 512, 256, 128)],
    "multi_hop_mix_quant": [{"block_f": v}
                            for v in (4096, 2048, 1024, 512, 256, 128)],
    "fused_retract": [{"block_d": d, "ns_iters": n}
                      for n in (10, 12, 16, 20) for d in (128, 256, 512)],
    "flash_attention": [{"block_q": bq, "block_kv": bk}
                        for bq in (64, 128, 256)
                        for bk in (64, 128, 256, 512)],
    "paged_decode": [{"pages_per_block": g} for g in (1, 2, 4, 8)],
}

#: kernels whose every candidate (default included) is accuracy-gated
#: against an *independent* oracle rather than the default config's output
ORACLE_GATED = ("flash_attention", "paged_decode")

#: knobs that still change the dispatched computation on the oracle (ref)
#: path — candidates differing only in other knobs are deduped there.
#: ``fused_retract``'s ns_iters is real work everywhere; flash attention's
#: block_kv drives the streaming oracle's chunk size.
REF_KNOBS = {
    "fused_retract": ("ns_iters",),
    "flash_attention": ("block_kv",),
}

#: fixed head geometry for the paged-decode probe (the cache key carries
#: (slots, pages, page_size, hd); heads only rescale every candidate alike)
PAGED_PROBE_HEADS = (4, 2)      # (h, hkv) — exercises GQA grouping

#: relative tolerance for accuracy-gated configs (vs the default config's
#: output on the same probe inputs)
ACCURACY_RTOL = 1e-5

#: a non-default config must beat the default by this margin to win —
#: keeps flat (CPU) block-shape timings from churning the cache on noise
HYSTERESIS = 0.05

_MEM: dict[str, tuple[float, dict]] = {}   # path -> (mtime, parsed cache)


# ---------------------------------------------------------------------------
# cache plumbing
# ---------------------------------------------------------------------------


def mode() -> str:
    m = os.environ.get("REPRO_TUNE", "load").lower()
    if m not in MODES:
        raise ValueError(f"REPRO_TUNE={m!r}: choose from {MODES}")
    return m


def cache_dir() -> str:
    return os.environ.get("REPRO_TUNE_DIR",
                          os.path.join(ROOT, "experiments", "tune"))


def _device_kind() -> str:
    import jax
    return jax.devices()[0].device_kind.replace(" ", "_")


def cache_path() -> str:
    return os.path.join(cache_dir(), f"{_device_kind()}.json")


def key(kernel: str, shape: tuple, dtype: Any,
        extra: Optional[dict] = None) -> str:
    k = f"{kernel}|{'x'.join(str(s) for s in shape)}|{dtype}"
    if extra:
        k += "|" + ",".join(f"{n}={v}" for n, v in sorted(extra.items()))
    return k


def _read_cache() -> dict:
    path = cache_path()
    try:
        mtime = os.path.getmtime(path)
    except OSError:
        return {"device_kind": _device_kind(), "entries": {}, "searches": 0}
    cached = _MEM.get(path)
    if cached is not None and cached[0] == mtime:
        return cached[1]
    with open(path) as f:
        data = json.load(f)
    _MEM[path] = (mtime, data)
    return data


def _write_cache(data: dict) -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    _MEM[path] = (os.path.getmtime(path), data)


def clear() -> None:
    """Drop the cache file for this device (tests / retuning)."""
    _MEM.clear()
    try:
        os.remove(cache_path())
    except OSError:
        pass


# ---------------------------------------------------------------------------
# the public trace-time hook
# ---------------------------------------------------------------------------


def lookup(kernel: str, shape: tuple, dtype: Any,
           extra: Optional[dict] = None) -> Optional[dict]:
    """Tuned config for this exact key, or None (→ caller ships defaults).

    ``load`` never measures; ``search`` runs :func:`autotune` once on a
    miss and serves the cache from then on."""
    m = mode()
    if m == "off" or kernel not in DEFAULTS:
        return None
    k = key(kernel, shape, dtype, extra)
    entry = _read_cache()["entries"].get(k)
    if entry is not None:
        return dict(entry["config"])
    if m == "search":
        return dict(autotune(kernel, shape, dtype, extra=extra)["config"])
    return None


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _dispatch_impl() -> str:
    from repro.kernels.ops import _default_impl
    return _default_impl()


def _probe_inputs(kernel: str, shape: tuple, dtype: Any, extra: dict):
    import jax
    import jax.numpy as jnp
    k0 = jax.random.PRNGKey(0)
    ks = jax.random.split(k0, 8)
    if kernel in ("ring_mix", "multi_hop_mix"):
        rows, f = shape
        mk = lambda i: jax.random.normal(ks[i], (rows, f), jnp.float32) \
            .astype(dtype)
        if kernel == "ring_mix":
            return (mk(0), mk(1), mk(2))
        return (mk(0),)
    if kernel in ("quant_mix", "multi_hop_mix_quant"):
        rows, f = shape
        q = lambda i: jax.random.randint(ks[i], (rows, f), -127, 128,
                                         jnp.int8)
        s = lambda i: jax.random.uniform(ks[4 + i], (rows, 1), jnp.float32,
                                         1e-3, 1e-1)
        if kernel == "quant_mix":
            return (q(0), q(1), q(2), s(0), s(1), s(2))
        return (q(0), s(0))
    if kernel == "fused_retract":
        d, r = shape
        x, _ = jnp.linalg.qr(jax.random.normal(ks[0], (d, r), jnp.float32))
        g = jax.random.normal(ks[1], (d, r), jnp.float32)
        return (x.astype(dtype), g.astype(dtype))
    if kernel == "flash_attention":
        b, s, t, h, hd = shape
        mk = lambda i, *sh: jax.random.normal(ks[i], sh, jnp.float32) \
            .astype(dtype)
        return (mk(0, b, s, h, hd), mk(1, b, t, h, hd), mk(2, b, t, h, hd))
    if kernel == "paged_decode":
        s, m, ps, hd = shape
        h, hkv = PAGED_PROBE_HEADS
        n_pages = s * m + 1                      # + the dump page
        q = jax.random.normal(ks[0], (s, h, hd), jnp.float32).astype(dtype)
        kp = jax.random.normal(ks[1], (n_pages, ps, hkv, hd),
                               jnp.float32).astype(dtype)
        vp = jax.random.normal(ks[2], (n_pages, ps, hkv, hd),
                               jnp.float32).astype(dtype)
        # ragged slots: slot i holds ~ (i+1)/s of the max context
        seq = jnp.asarray([max(1, ((i + 1) * m * ps) // s)
                           for i in range(s)], jnp.int32)
        bt = jnp.asarray(
            [[1 + i * m + j if j * ps < int(seq[i]) else -1
              for j in range(m)] for i in range(s)], jnp.int32)
        return (q, kp, vp, bt, seq)
    raise ValueError(f"no probe for kernel {kernel!r}")


def _probe_fn(kernel: str, shape: tuple, config: dict, extra: dict,
              impl: str):
    """A jittable callable honoring ``config`` under the current dispatch
    (Pallas on TPU, the jnp oracle elsewhere — where block shapes are
    no-ops but ``ns_iters`` is real work)."""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    interp = impl == "pallas_interpret"
    wc, ws = 1.0 / 3.0, 1.0 / 3.0
    if kernel == "ring_mix":
        if impl == "ref":
            return jax.jit(functools.partial(ref.ring_mix_ref,
                                             w_self=wc, w_side=ws))
        from repro.kernels import ring_mix as _rm
        return functools.partial(_rm.ring_mix_flat, w_self=wc, w_side=ws,
                                 block_rows=config["block_rows"],
                                 interpret=interp)
    if kernel == "quant_mix":
        if impl == "ref":
            return jax.jit(functools.partial(ref.quant_mix_ref,
                                             w_self=wc, w_side=ws))
        from repro.kernels import quant_mix as _qm
        return functools.partial(_qm.quant_mix_2d, w_self=wc, w_side=ws,
                                 block_cols=config["block_cols"],
                                 interpret=interp)
    if kernel == "multi_hop_mix":
        hops = int(extra.get("hops", 3))
        rows = shape[0]
        kw = dict(hops=hops, out_rows=max(rows - 2 * hops, 1), halo=hops,
                  w_self=wc, w_side=ws)
        if impl == "ref":
            return jax.jit(functools.partial(ref.multi_hop_mix_ref, **kw))
        from repro.kernels import multi_hop_mix as _mh
        return functools.partial(_mh.multi_hop_mix_flat, **kw,
                                 block_f=config["block_f"], interpret=interp)
    if kernel == "multi_hop_mix_quant":
        hops = int(extra.get("hops", 3))
        kw = dict(hops=hops, w_self=wc, w_side=ws)
        if impl == "ref":
            return jax.jit(functools.partial(ref.multi_hop_mix_quant_ref,
                                             **kw))
        from repro.kernels import multi_hop_mix as _mh
        return functools.partial(_mh.multi_hop_mix_quant_flat, **kw,
                                 block_f=config["block_f"], interpret=interp)
    if kernel == "fused_retract":
        if impl == "ref":
            return jax.jit(functools.partial(
                ref.fused_retract_ref, ns_iters=config["ns_iters"]))
        from repro.kernels import retract as _rt
        return functools.partial(_rt.fused_retract_2d,
                                 block_d=config["block_d"],
                                 ns_iters=config["ns_iters"],
                                 interpret=interp)
    # the attention kernels route through their ops.py wrappers — explicit
    # block args skip the tune lookup, so probing never recurses into the
    # cache being built
    from repro.kernels import ops as _ops
    if kernel == "flash_attention":
        return jax.jit(functools.partial(
            _ops.flash_attention, causal=True, impl=impl,
            block_q=config["block_q"], block_kv=config["block_kv"]))
    if kernel == "paged_decode":
        return jax.jit(functools.partial(
            _ops.paged_decode_attention, impl=impl,
            pages_per_block=config["pages_per_block"]))
    raise ValueError(f"no probe for kernel {kernel!r}")


def _oracle_fn(kernel: str):
    """The independent accuracy oracle for ORACLE_GATED kernels."""
    from repro.kernels import ref
    if kernel == "flash_attention":
        import functools
        return functools.partial(ref.attention_naive, causal=True)
    if kernel == "paged_decode":
        return ref.paged_decode_attention_ref
    raise ValueError(kernel)


def _default_for_shape(kernel: str, shape: tuple) -> dict:
    """The config ops.py would actually ship for this shape with no cache —
    the nominal DEFAULTS entry, stepped down the same fallback ladder ops.py
    uses when the nominal block doesn't tile the shape."""
    cfg = dict(DEFAULTS[kernel])
    rows, f = shape[0], shape[-1]
    if "block_rows" in cfg:
        for cand in (cfg["block_rows"], 128, 64, 32, 16, 8):
            if rows % cand == 0:
                cfg["block_rows"] = cand
                break
        else:
            cfg["block_rows"] = rows
    if "block_cols" in cfg:
        for cand in (cfg["block_cols"], 1024, 512, 256, 128):
            if f % cand == 0:
                cfg["block_cols"] = cand
                break
        else:
            cfg["block_cols"] = f
    if "block_f" in cfg:
        for cand in (cfg["block_f"], 4096, 2048, 512, 256, 128):
            if f % cand == 0:
                cfg["block_f"] = cand
                break
        else:
            cfg["block_f"] = f
    if "block_d" in cfg and rows % cfg["block_d"]:
        cfg["block_d"] = 128 if rows % 128 == 0 else rows
    return cfg


def _feasible(kernel: str, shape: tuple, config: dict) -> bool:
    rows, f = shape[0], shape[-1]
    if "block_rows" in config:
        return rows % config["block_rows"] == 0
    if "block_cols" in config:
        return f % config["block_cols"] == 0
    if "block_f" in config:
        return f % config["block_f"] == 0
    if "block_d" in config:
        return rows % config["block_d"] == 0
    return True


def _time_us(fn, args, repeats: int = 5, inner: int = 3) -> float:
    import jax
    jax.block_until_ready(fn(*args))             # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / inner)
    return best * 1e6


def _estimate(kernel: str, shape: tuple, config: dict, extra: dict):
    from repro.obs import estimates as est
    if kernel == "ring_mix":
        return est.ring_mix_est(shape[0] * shape[1])
    if kernel == "quant_mix":
        return est.quant_mix_est(shape[0], shape[1])
    if kernel in ("multi_hop_mix", "multi_hop_mix_quant"):
        hops = int(extra.get("hops", 3))
        return est.multi_hop_mix_est(
            shape[0], shape[1], hops=hops,
            out_rows=max(shape[0] - 2 * hops, 1),
            quant=kernel.endswith("quant"))
    if kernel == "fused_retract":
        return est.fused_retract_est(shape[0], shape[1],
                                     ns_iters=config.get("ns_iters", 20))
    if kernel == "flash_attention":
        b, s, t, h, hd = shape
        return est.flash_attention_est(b, s, t, h, hd,
                                       block_q=config.get("block_q", 128))
    if kernel == "paged_decode":
        s, m, ps, hd = shape
        h, hkv = PAGED_PROBE_HEADS
        return est.paged_decode_est(s, h, hkv, hd, m, ps)
    raise ValueError(kernel)


# ---------------------------------------------------------------------------
# the search
# ---------------------------------------------------------------------------


def autotune(kernel: str, shape: tuple, dtype: Any,
             extra: Optional[dict] = None, force: bool = False) -> dict:
    """Measure every feasible candidate, gate accuracy-sensitive ones, pick
    the winner (with hysteresis vs the default), persist, return the record.
    """
    import numpy as np

    from repro.launch import roofline

    extra = dict(extra or {})
    k = key(kernel, shape, dtype, extra)
    cache = _read_cache()
    if not force and k in cache["entries"]:
        return cache["entries"][k]

    impl = _dispatch_impl()
    default = _default_for_shape(kernel, shape)
    args = _probe_inputs(kernel, shape, dtype, extra)
    # two gating flavors: self-gated kernels (ns_iters changes the math, so
    # non-default candidates compare against the default config's output);
    # ORACLE_GATED kernels check *every* candidate — default included —
    # against an independent reference oracle
    oracle = kernel in ORACLE_GATED
    gated = oracle or "ns_iters" in default
    ref_out = None
    if oracle:
        ref_out = np.asarray(_oracle_fn(kernel)(*args))
    elif gated:
        ref_out = np.asarray(
            _probe_fn(kernel, shape, default, extra, impl)(*args))
    if gated:
        ref_scale = max(1.0, float(np.max(np.abs(ref_out))))

    candidates = []
    seen: set[tuple] = set()
    ref_knobs = REF_KNOBS.get(kernel, ())
    for cfg in [default] + SPACES[kernel]:
        # on the oracle path only math-bearing knobs differentiate
        # candidates (block shapes are no-ops there) — dedupe so the search
        # stays cheap; the default always survives as the first entry
        sig = tuple(sorted(cfg.items())) if impl != "ref" else \
            tuple(sorted((n, v) for n, v in cfg.items() if n in ref_knobs))
        if sig in seen or not _feasible(kernel, shape, cfg):
            continue
        seen.add(sig)
        fn = _probe_fn(kernel, shape, cfg, extra, impl)
        rec = {"config": cfg, "us": _time_us(fn, args)}
        if gated and (oracle or cfg != default):
            err = float(np.max(np.abs(np.asarray(fn(*args)) - ref_out)))
            rec["max_abs_err"] = err
            rec["accurate"] = bool(err <= ACCURACY_RTOL * ref_scale)
        candidates.append(rec)

    default_us = next(c["us"] for c in candidates
                      if c["config"] == default)
    ok = [c for c in candidates if c.get("accurate", True)]
    if not ok:
        raise RuntimeError(
            f"{kernel}: no candidate met the accuracy gate "
            f"(rtol={ACCURACY_RTOL}) — kernel/oracle mismatch")
    best = min(ok, key=lambda c: c["us"])
    if best["config"] != default and \
            best["us"] > default_us * (1.0 - HYSTERESIS):
        best = next((c for c in ok if c["config"] == default), best)

    est = _estimate(kernel, shape, best["config"], extra)
    entry = {
        "config": best["config"],
        "default_config": default,
        "best_us": best["us"],
        "default_us": default_us,
        "speedup_pct": 100.0 * (default_us / max(best["us"], 1e-9) - 1.0),
        "impl": impl,
        "candidates": candidates,
        "roofline": roofline.place(est),
        "searched_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    cache = _read_cache()
    cache["entries"][k] = entry
    cache["searches"] = cache.get("searches", 0) + 1
    cache["device_kind"] = _device_kind()
    _write_cache(cache)
    return entry


# ---------------------------------------------------------------------------
# CLI — the CI tune job's entry point
# ---------------------------------------------------------------------------

#: small default shape set: quick on CPU, representative of the bench sizes
DEMO_SHAPES = [
    ("ring_mix", (64, 1024), "float32", None),
    ("multi_hop_mix", (16, 1024), "float32", {"hops": 3}),
    ("fused_retract", (256, 64), "float32", None),
    ("flash_attention", (1, 128, 128, 4, 64), "float32", None),
    ("paged_decode", (4, 8, 16, 64), "float32", None),
]


def main(argv: Optional[list] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Autotune Pallas kernel launch configs "
                    "(cache: experiments/tune/<device>.json)")
    ap.add_argument("--kernel", action="append", default=None,
                    help="kernel[:RxC[:hops]] — repeatable; default: a "
                         "small demo set")
    ap.add_argument("--force", action="store_true",
                    help="re-search even on cache hits")
    args = ap.parse_args(argv)

    demo_shape = {n: s for n, s, _, _ in DEMO_SHAPES}
    todo = []
    for spec in args.kernel or []:
        parts = spec.split(":")
        name = parts[0]
        shape = tuple(int(v) for v in parts[1].split("x")) if len(parts) > 1 \
            else demo_shape.get(name, (64, 1024))
        extra = {"hops": int(parts[2])} if len(parts) > 2 else (
            {"hops": 3} if name.startswith("multi_hop_mix") else None)
        todo.append((name, shape, "float32", extra))
    if not todo:
        todo = DEMO_SHAPES

    for name, shape, dtype, extra in todo:
        entry = autotune(name, shape, dtype, extra=extra, force=args.force)
        print(f"{key(name, shape, dtype, extra)}: config={entry['config']} "
              f"default={entry['default_us']:.1f}us "
              f"best={entry['best_us']:.1f}us "
              f"({entry['speedup_pct']:+.1f}%)")
    print(f"cache: {cache_path()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
