"""Stiefel tangent-projection Pallas kernels.

The optimizer-step hot spot of DRGDA/DRSGDA: for every Stiefel leaf
(x, g in R^{d x r}) compute

    P_{T_x}(g) = g - x * sym(x^T g)

Two kernels, both tiled over the tall ``d`` dimension so VMEM holds
(block_d, r) panels and the MXU sees (block_d x r)·(block_d x r) matmuls:

  1. ``gram``  — S = sym(x^T g), accumulated over d-blocks in an (r, r)
     VMEM scratch; symmetrization fused into the final write.
  2. ``apply`` — out = g - x @ S, streamed over the same d-blocks.

``r`` is padded to a multiple of 128 by the ops.py wrapper (MXU lane
alignment); d to a multiple of block_d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_D = 256


def _gram_kernel(x_ref, g_ref, s_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _finalize():
        a = acc_ref[...]
        s_ref[...] = (0.5 * (a + a.T)).astype(s_ref.dtype)


def _apply_kernel(x_ref, g_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (g_ref[...].astype(jnp.float32) - jax.lax.dot_general(
        x, s, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def stiefel_project_2d(x: Array, g: Array, *, block_d: int = DEFAULT_BLOCK_D,
                       interpret: bool = False) -> Array:
    """P_{T_x}(g) for a single (d, r) pair; d % block_d == 0 (ops.py pads)."""
    d, r = x.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, (d, block_d)
    n_d = d // block_d

    sym = pl.pallas_call(
        _gram_kernel,
        grid=(n_d,),
        in_specs=[
            pl.BlockSpec((block_d, r), lambda i: (i, 0)),
            pl.BlockSpec((block_d, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((r, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((r, r), jnp.float32),
        scratch_shapes=[pltpu.VMEM((r, r), jnp.float32)],
        interpret=interpret,
        name="stiefel_gram",
    )(x, g)

    return pl.pallas_call(
        _apply_kernel,
        grid=(n_d,),
        in_specs=[
            pl.BlockSpec((block_d, r), lambda i: (i, 0)),
            pl.BlockSpec((block_d, r), lambda i: (i, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_d, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d, r), g.dtype),
        interpret=interpret,
        name="stiefel_apply",
    )(x, g, sym)
