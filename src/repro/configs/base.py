"""Config schema: architectures, block programs, mesh factorizations, shapes.

A model is described as a *program*: an ordered list of :class:`Stage`s, each
a supercell of distinct block specs repeated ``repeat`` times.  Repeats are
executed with ``jax.lax.scan`` over layer-stacked parameters, which keeps
compile time flat in depth (60-layer models compile one supercell body).
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence

BlockKind = Literal["attn", "moe_attn", "mamba", "mlstm", "slstm"]


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Attention flavour for one block."""
    kind: Literal["gqa", "mla"] = "gqa"
    sliding_window: Optional[int] = None      # None => full causal
    cross_attn: bool = False                  # adds a cross-attn sublayer (VLM)
    # MLA (DeepSeek-V2) dims — used when kind == "mla"
    q_lora_rank: int = 0                      # 0 => no q compression
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int = 8
    top_k: int = 2
    d_expert: int = 0                  # expert hidden dim (d_ff of one expert)
    n_shared: int = 0                  # always-on shared experts (DeepSeek-V2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # §Perf knob: dispatch tokens in G independent groups (align G with the
    # fsdp axis so sort/capacity/gather stay shard-local and the giant
    # token all-gather disappears; capacity becomes per-group).
    # -1 = per-sequence (batch-dim) groups.
    dispatch_groups: int = 1
    # §Perf knob: name the group axis as an SPMD mesh axis so the
    # partitioner pins the vmapped dispatch to it (requires the axis to
    # exist in the active mesh, e.g. "fsdp" on the training mesh).
    dispatch_spmd_axis: str = ""
    # §Perf knob: pin the dispatched (E, C, d) expert activations' E dim to
    # this mesh axis with an explicit sharding constraint — without it
    # GSPMD REPLICATES xe/h across all devices (300 GiB/layer f32 for
    # DeepSeek-V2) instead of resharding to the expert-parallel layout.
    expert_shard_axis: str = ""


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    """Mamba2 (SSD) block."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    """mLSTM / sLSTM cells (xLSTM)."""
    proj_factor: float = 2.0       # mLSTM up-projection
    conv_window: int = 4
    chunk: int = 256
    slstm_proj_factor: float = 4.0 / 3.0


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    kind: BlockKind = "attn"
    attn: Optional[AttnSpec] = None
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    xlstm: Optional[XLSTMSpec] = None
    has_mlp: bool = True               # dense MLP (ignored for moe/mamba/xlstm)


@dataclasses.dataclass(frozen=True)
class Stage:
    blocks: tuple[BlockSpec, ...]      # one supercell
    repeat: int = 1                    # scanned repeats


@dataclasses.dataclass(frozen=True)
class FrontendSpec:
    """Stubbed modality frontend (the one allowed carve-out): provides
    precomputed embeddings of the right shape via input_specs()."""
    kind: Literal["vision", "audio_cond"] = "vision"
    n_tokens: int = 576                # image patch tokens / conditioning frames
    embed_dim: int = 1152              # frontend output dim (projected to d_model)


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Factorization of the per-pod 256-chip grid into logical axes.

    node * fsdp * model == 256.  ``node`` is the decentralized (gossip)
    dimension; ``fsdp`` shards each node's replica; ``model`` is tensor/
    expert parallelism.  Multi-pod runs add a leading pod axis and extend the
    gossip ring across pods.
    """
    node: int = 16
    fsdp: int = 1
    model: int = 16

    def __post_init__(self):
        assert self.node * self.fsdp * self.model == 256, \
            f"mesh plan must cover 256 chips/pod, got {self}"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: Literal["dense", "moe", "hybrid", "vlm", "audio", "ssm"] = "dense"
    d_model: int = 512
    n_heads: int = 8
    n_kv_heads: int = 8
    d_ff: int = 2048
    vocab_size: int = 32000
    head_dim: int = 0                  # 0 => d_model // n_heads
    stages: tuple[Stage, ...] = ()
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    n_codebooks: int = 1               # musicgen: 4 parallel EnCodec streams
    frontend: Optional[FrontendSpec] = None
    max_seq_len: int = 131072
    # which parameters are manifold-constrained: path-regex over '/'-joined
    # key paths.  Only tall/square (d >= r) matches are constrained (the map
    # builder filters); the rest stay Euclidean — see DESIGN.md
    # §Arch-applicability.
    manifold_policy: str = (
        r"attn/(wq|wk|wv|wo|w_dq|w_dkv)$|mlstm/(wq|wk|wv|w_down)$")
    # which geometry the policy-matched leaves live on: a repro.geometry
    # registry name — "stiefel" (orthonormal, the paper), "grassmann"
    # (subspace-only), "oblique" (unit columns, normalized layers), "sphere"
    manifold: str = "stiefel"
    # DRO group count for the minimax objective
    n_groups: int = 8
    rho: float = 1.0                   # strong-concavity coefficient (Eq. 20/21)
    mesh_plan: MeshPlan = MeshPlan()
    remat: bool = True
    dtype: str = "bfloat16"
    # lax.scan over stage repeats (production).  The dry-run's differential
    # cost analysis compiles shallow UNROLLED variants (use_scan=False)
    # because XLA cost_analysis counts a while-loop body once, not
    # trip_count times.
    use_scan: bool = True
    # §Perf knob: "gather" = take_along_axis on the (vocab-sharded) logits;
    # "dot" = one-hot contraction (partial sums + small all-reduce, no
    # logits all-gather when the vocab dim is model-sharded).
    ce_impl: str = "gather"
    # §Perf knob: pad embedding/unembedding rows to a multiple of this so
    # an odd vocab (granite: 49155) becomes model-axis-shardable and the
    # full-logits all-reduce disappears (Megatron-style vocab padding).
    # 0 = no padding.  Loss masks the padded logits.
    vocab_pad_to: int = 0
    # --- communication layer knobs (repro.comms) ---------------------------
    # Compression of gossip payloads: "none" | "int8" | "topk" | "lowrank".
    comm_compressor: str = "none"
    comm_topk_frac: float = 0.05       # kept fraction per node (topk)
    comm_rank: int = 4                 # retained rank per matrix leaf (lowrank)
    comm_gamma: float = 0.9            # CHOCO consensus step on the hats
    comm_error_feedback: bool = True   # False => naive quantized gossip
    # Which hops of a k>1 int8 round are compressed: "first" ships the
    # payload once then mixes hats in fp32; "all" requantizes at every hop
    # so only int8 bytes ever travel.
    comm_quant_hops: str = "first"
    # Channel faults / time-varying topology for each gossip hop.
    comm_drop_rate: float = 0.0
    comm_straggler_rate: float = 0.0
    comm_schedule: str = "static"      # static | round_robin | matching
    # How gossip hops execute (repro.comms.backend): "stacked" keeps the
    # node axis as leaf axis 0 on every device (roll/einsum mixing);
    # "shard_map" maps it onto the training mesh's node axis (neighbour
    # ppermute exchange); "auto" picks shard_map whenever build_trainer is
    # given a mesh with a >1-device node axis.
    mix_backend: str = "auto"

    def comm_spec(self):
        """repro.comms.CommSpec from the comm_* knobs, or None when the
        communication layer is a no-op (exact, lossless gossip)."""
        if (self.comm_compressor == "none" and self.comm_drop_rate == 0.0
                and self.comm_straggler_rate == 0.0
                and self.comm_schedule == "static"):
            return None
        from repro.comms.spec import CommSpec  # lazy: keep schema jax-free
        return CommSpec(compressor=self.comm_compressor,
                        topk_frac=self.comm_topk_frac, rank=self.comm_rank,
                        gamma=self.comm_gamma,
                        error_feedback=self.comm_error_feedback,
                        quant_hops=self.comm_quant_hops,
                        drop_rate=self.comm_drop_rate,
                        straggler_rate=self.comm_straggler_rate,
                        schedule=self.comm_schedule)

    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to <= 0:
            return self.vocab_size
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_layers(self) -> int:
        return sum(len(s.blocks) * s.repeat for s in self.stages)

    def flat_blocks(self) -> list[BlockSpec]:
        out: list[BlockSpec] = []
        for s in self.stages:
            out.extend(list(s.blocks) * s.repeat)
        return out


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def uniform_stages(block: BlockSpec, n_layers: int) -> tuple[Stage, ...]:
    return (Stage(blocks=(block,), repeat=n_layers),)


def patterned_stages(cell: Sequence[BlockSpec], n_layers: int) -> tuple[Stage, ...]:
    """Repeat a supercell; a trailing partial cell becomes its own stage."""
    c = len(cell)
    full, rem = divmod(n_layers, c)
    stages = []
    if full:
        stages.append(Stage(blocks=tuple(cell), repeat=full))
    if rem:
        stages.append(Stage(blocks=tuple(cell[:rem]), repeat=1))
    return tuple(stages)
