"""xLSTM 1.3B [ssm] — mLSTM blocks with sLSTM every 8th (the paper's
mixed-cell ratio).  [arXiv:2405.04517]

48L  d_model=2048  4H  d_ff=0 (cells carry their own projections)
vocab=50304.
"""
from repro.configs.base import (BlockSpec, MeshPlan, ModelConfig, XLSTMSpec,
                                patterned_stages)

_XS = XLSTMSpec(proj_factor=2.0, conv_window=4, chunk=256)
_M = BlockSpec(kind="mlstm", xlstm=_XS)
_S = BlockSpec(kind="slstm", xlstm=_XS)

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    # 7 mLSTM : 1 sLSTM supercell; 48 = 8*6
    stages=patterned_stages([_M] * 7 + [_S], 48),
    n_groups=8,
    mesh_plan=MeshPlan(node=8, fsdp=2, model=16),
)

_XS_SMK = XLSTMSpec(proj_factor=2.0, conv_window=4, chunk=32)

SMOKE = ModelConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    stages=patterned_stages(
        [BlockSpec(kind="mlstm", xlstm=_XS_SMK),
         BlockSpec(kind="slstm", xlstm=_XS_SMK)], 2),
    n_groups=4,
    remat=False,
)
