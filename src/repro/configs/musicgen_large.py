"""MusicGen-large [audio] — decoder-only transformer over 4 parallel
EnCodec codebook streams (embeddings summed, 4 output heads); the EnCodec
conv codec itself is the stubbed frontend per the carve-out.
[arXiv:2306.05284]

48L  d_model=2048  32H (kv=32)  d_ff=8192  vocab=2048 (codebook size).
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                uniform_stages)

_BLK = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    n_codebooks=4,
    stages=uniform_stages(_BLK, 48),
    n_groups=8,
    mesh_plan=MeshPlan(node=8, fsdp=2, model=16),
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="audio",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=64,
    n_codebooks=4,
    stages=uniform_stages(_BLK, 2),
    n_groups=4,
    remat=False,
)
