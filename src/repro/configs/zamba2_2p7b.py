"""Zamba2 2.7B [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

54L  d_model=2560  32H (kv=32)  d_ff=10240  ssm_state=64  vocab=32000.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                SSMSpec, patterned_stages)

_MAMBA = BlockSpec(kind="mamba",
                   ssm=SSMSpec(d_state=64, d_conv=4, expand=2, head_dim=64,
                               n_groups=1, chunk=256))
_ATTN = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    # 5 mamba : 1 shared-attention supercell; 54 = 6*9
    stages=patterned_stages([_MAMBA] * 5 + [_ATTN], 54),
    n_groups=8,
    mesh_plan=MeshPlan(node=8, fsdp=2, model=16),
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke",
    family="hybrid",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    stages=patterned_stages(
        [BlockSpec(kind="mamba",
                   ssm=SSMSpec(d_state=8, head_dim=16, chunk=32)),
         _ATTN], 2),
    n_groups=4,
    remat=False,
)
