"""Config registry: the 10 assigned architectures (+ paper CNN) and the
4 input shapes, plus ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (INPUT_SHAPES, AttnSpec, BlockSpec, InputShape,
                                MeshPlan, ModelConfig, Stage)

_MODULES = {
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "smollm-135m": "repro.configs.smollm_135m",
    "musicgen-large": "repro.configs.musicgen_large",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "xlstm-1.3b": "repro.configs.xlstm_1p3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


# ---------------------------------------------------------------------------
# long-context variant: cap every full-attention layer with a sliding window
# ---------------------------------------------------------------------------

LONG_CONTEXT_WINDOW = 16384


def long_context_override(cfg: ModelConfig,
                          window: int = LONG_CONTEXT_WINDOW) -> ModelConfig:
    """Replace unbounded attention with a sliding window (block-sparse
    variant used only for the ``long_500k`` shape on attention archs; native
    SSM/hybrid layers are untouched).  Recorded as a VARIANT in
    EXPERIMENTS.md, not the paper arch."""

    def fix_block(b: BlockSpec) -> BlockSpec:
        if b.kind in ("attn", "moe_attn") and b.attn.sliding_window is None:
            return dataclasses.replace(
                b, attn=dataclasses.replace(b.attn, sliding_window=window))
        return b

    stages = tuple(
        dataclasses.replace(st, blocks=tuple(fix_block(b) for b in st.blocks))
        for st in cfg.stages)
    return dataclasses.replace(cfg, stages=stages,
                               name=cfg.name + f"+swa{window}")


def needs_long_context_override(cfg: ModelConfig) -> bool:
    return any(b.kind in ("attn", "moe_attn") and b.attn.sliding_window is None
               for st in cfg.stages for b in st.blocks)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape | str,
                n_nodes: Optional[int] = None,
                activation_dtype=jnp.bfloat16) -> dict:
    """Stand-ins for every model input of (cfg, shape).

    * train: node-stacked {tokens, group_ids[, frontend_embeds]} — leading
      axis ``n_nodes`` (required), per-node batch = global_batch / n_nodes.
    * prefill: global {tokens[, frontend_embeds]}.
    * decode: {token, position, cache} for ``serve_step`` — the cache holds
      ``seq_len`` entries (positions 0..seq_len-2 filled, one slot for the
      new token at position seq_len-1).
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    tok_shape: tuple[int, ...]

    if shape.mode == "train":
        assert n_nodes, "train specs need n_nodes"
        assert b % n_nodes == 0, (b, n_nodes)
        pb = b // n_nodes
        tok = (n_nodes, pb, s) if cfg.n_codebooks == 1 else \
            (n_nodes, pb, s, cfg.n_codebooks)
        out = {"tokens": _sds(tok, jnp.int32),
               "group_ids": _sds((n_nodes, pb), jnp.int32)}
        if cfg.frontend is not None:
            out["frontend_embeds"] = _sds(
                (n_nodes, pb, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
                activation_dtype)
        return out

    if shape.mode == "prefill":
        tok = (b, s) if cfg.n_codebooks == 1 else (b, s, cfg.n_codebooks)
        out = {"tokens": _sds(tok, jnp.int32)}
        if cfg.frontend is not None:
            out["frontend_embeds"] = _sds(
                (b, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
                activation_dtype)
        return out

    # decode
    from repro.models import transformer as T  # local import (cycle-free)
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, b, s, dtype=activation_dtype))
    tok = (b,) if cfg.n_codebooks == 1 else (b, cfg.n_codebooks)
    out = {"token": _sds(tok, jnp.int32),
           "position": _sds((b,), jnp.int32),
           "cache": cache}
    if cfg.frontend is not None:
        out["frontend_embeds"] = _sds(
            (b, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
            activation_dtype)
    return out
