"""Gemma-3 27B [dense] — GQA, 5:1 local:global sliding-window pattern, 128k
context.  [hf:google/gemma-3-1b-pt family card]

62L  d_model=5376  32H (kv=16)  d_ff=21504  vocab=262144.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                patterned_stages)

_LOCAL = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa", sliding_window=1024))
_GLOBAL = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    # 5 local : 1 global supercell; 62 = 6*10 + 2
    stages=patterned_stages([_LOCAL] * 5 + [_GLOBAL], 62),
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    n_groups=8,
    mesh_plan=MeshPlan(node=4, fsdp=4, model=16),
)

SMOKE = ModelConfig(
    name="gemma3-27b-smoke",
    family="dense",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    stages=patterned_stages(
        [BlockSpec(kind="attn", attn=AttnSpec(kind="gqa", sliding_window=8)),
         BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))], 2),
    n_groups=4,
    remat=False,
)
