"""Granite-3.0 8B base [dense] — GQA.  [hf:ibm-granite/granite-3.0-2b-base
family card]

40L  d_model=4096  32H (kv=8)  d_ff=12800  vocab=49155.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                uniform_stages)

_BLK = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    stages=uniform_stages(_BLK, 40),
    n_groups=8,
    mesh_plan=MeshPlan(node=8, fsdp=2, model=16),
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke",
    family="dense",
    d_model=160,
    n_heads=8,
    n_kv_heads=2,
    head_dim=20,
    d_ff=320,
    vocab_size=256,
    stages=uniform_stages(_BLK, 2),
    n_groups=4,
    remat=False,
)
