"""Granite-3.0 1B-A400M base [moe] — 32 routed experts, top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]

24L  d_model=1024  16H (kv=8)  d_ff(expert)=512  vocab=49155.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                MoESpec, uniform_stages)

_BLK = BlockSpec(
    kind="moe_attn",
    attn=AttnSpec(kind="gqa"),
    moe=MoESpec(n_experts=32, top_k=8, d_expert=512, capacity_factor=1.25),
)

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    stages=uniform_stages(_BLK, 24),
    n_groups=8,
    mesh_plan=MeshPlan(node=16, fsdp=1, model=16),
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=64,
    vocab_size=256,
    stages=uniform_stages(
        BlockSpec(kind="moe_attn", attn=AttnSpec(kind="gqa"),
                  moe=MoESpec(n_experts=4, top_k=2, d_expert=64,
                              capacity_factor=2.0)), 2),
    n_groups=4,
    remat=False,
)
