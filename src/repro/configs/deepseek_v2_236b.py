"""DeepSeek-V2 236B [moe] — MLA (kv_lora=512) + 160 routed experts top-6,
2 shared experts, first layer dense.  [arXiv:2405.04434]

60L  d_model=5120  128H  d_ff(expert)=1536  vocab=102400.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                MoESpec, Stage)

_MLA = AttnSpec(kind="mla", q_lora_rank=1536, kv_lora_rank=512,
                qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128)
_MOE = MoESpec(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
               capacity_factor=1.25, router_aux_coef=0.003)

_FIRST = BlockSpec(kind="attn", attn=_MLA, has_mlp=True)        # dense layer 0
_MOE_BLOCK = BlockSpec(kind="moe_attn", attn=_MLA, moe=_MOE)

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=12288,                      # dense first-layer FFN
    vocab_size=102400,
    stages=(Stage(blocks=(_FIRST,), repeat=1),
            Stage(blocks=(_MOE_BLOCK,), repeat=59)),
    rope_theta=10000.0,
    n_groups=8,
    mesh_plan=MeshPlan(node=2, fsdp=8, model=16),
)

_SMK_MLA = AttnSpec(kind="mla", q_lora_rank=64, kv_lora_rank=32,
                    qk_rope_head_dim=16, qk_nope_head_dim=32, v_head_dim=32)
_SMK_MOE = MoESpec(n_experts=4, top_k=2, d_expert=64, n_shared=1,
                   capacity_factor=2.0)

SMOKE = ModelConfig(
    name="deepseek-v2-236b-smoke",
    family="moe",
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    stages=(Stage(blocks=(BlockSpec(kind="attn", attn=_SMK_MLA),), repeat=1),
            Stage(blocks=(BlockSpec(kind="moe_attn", attn=_SMK_MLA,
                                    moe=_SMK_MOE),), repeat=1)),
    n_groups=4,
    remat=False,
)
