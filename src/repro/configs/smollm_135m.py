"""SmolLM-135M [dense] — llama-architecture small model; also the
end-to-end training driver arch.  [hf:HuggingFaceTB/SmolLM-135M]

30L  d_model=576  9H (kv=3)  d_ff=1536  vocab=49152.

Note: 9 heads do not divide the 16-way model axis — attention parameters
are replicated over ``model`` (tiny model, data-parallel dominant) while
MLP and vocab shard; see sharding/partition.py fallback rule.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                uniform_stages)

_BLK = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    stages=uniform_stages(_BLK, 30),
    n_groups=8,
    mesh_plan=MeshPlan(node=16, fsdp=1, model=16),
)

SMOKE = ModelConfig(
    name="smollm-135m-smoke",
    family="dense",
    d_model=96,
    n_heads=3,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    stages=uniform_stages(_BLK, 2),
    n_groups=4,
    remat=False,
)
