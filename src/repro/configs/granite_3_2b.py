"""Granite-3.0 2B base [dense] — GQA.  [hf:ibm-granite/granite-3.0-2b-base]

40L  d_model=2048  32H (kv=8)  d_ff=8192  vocab=49155.
"""
from repro.configs.base import (AttnSpec, BlockSpec, MeshPlan, ModelConfig,
                                uniform_stages)

_BLK = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    stages=uniform_stages(_BLK, 40),
    n_groups=8,
    mesh_plan=MeshPlan(node=16, fsdp=1, model=16),
)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke",
    family="dense",
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=256,
    stages=uniform_stages(_BLK, 2),
    n_groups=4,
    remat=False,
)
