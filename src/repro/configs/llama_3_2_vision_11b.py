"""Llama-3.2 11B Vision [vlm] — text decoder with cross-attention image
layers every 5th block; vision encoder STUBBED (precomputed patch
embeddings).  [hf:meta-llama/Llama-3.2-11B-Vision]

40L  d_model=4096  32H (kv=8)  d_ff=14336  vocab=128256.
"""
from repro.configs.base import (AttnSpec, BlockSpec, FrontendSpec, MeshPlan,
                                ModelConfig, patterned_stages)

_SELF = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa"))
_XATTN = BlockSpec(kind="attn", attn=AttnSpec(kind="gqa", cross_attn=True))

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    # cross-attn every 5th layer; 40 = 5*8
    stages=patterned_stages([_SELF] * 4 + [_XATTN], 40),
    frontend=FrontendSpec(kind="vision", n_tokens=1600, embed_dim=1280),
    n_groups=8,
    mesh_plan=MeshPlan(node=8, fsdp=2, model=16),
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=256,
    stages=patterned_stages([_SELF, _XATTN], 2),
    frontend=FrontendSpec(kind="vision", n_tokens=16, embed_dim=48),
    n_groups=4,
    remat=False,
)
