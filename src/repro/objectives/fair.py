"""Orthonormal fair classification (paper Eqs. 19–20) and distributionally
robust optimization (Eq. 21) on a small CNN — the paper's own experiments.

    min_{w in St}  max_{u in Delta_3}  sum_i u_i L_i(w) - rho ||u||^2   (fair)
    min_{w in St}  max_{p in Delta_G}  sum_g p_g l_g(w) - ||p - 1/G||^2 (DRO)

The CNN mirrors the paper's setup (conv-conv-fc-fc on 28x28-class images;
our synthetic stream uses 14x14 by default to keep CPU tests fast).  The
fully-connected weights are Stiefel-constrained (tall matrices); conv
kernels stay Euclidean.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.minimax import MinimaxProblem, project_simplex
from repro.models.layers import orthogonal_init

Array = jax.Array


# ---------------------------------------------------------------------------
# small CNN
# ---------------------------------------------------------------------------


def init_cnn(key, image_hw: int = 14, channels: int = 1, n_classes: int = 3,
             c1: int = 8, c2: int = 16, fc: int = 64) -> dict:
    ks = jax.random.split(key, 4)
    flat = (image_hw // 4) * (image_hw // 4) * c2
    return {
        "conv1": jax.random.normal(ks[0], (3, 3, channels, c1)) * 0.2,
        "conv2": jax.random.normal(ks[1], (3, 3, c1, c2)) * 0.1,
        "fc1": orthogonal_init(ks[2], flat, fc),            # Stiefel leaf
        "head": orthogonal_init(ks[3], fc, n_classes),      # Stiefel leaf
    }


def cnn_manifold_map(params: dict) -> dict:
    return {"conv1": "euclidean", "conv2": "euclidean",
            "fc1": "stiefel", "head": "stiefel"}


def cnn_stiefel_mask(params: dict) -> dict:
    """Legacy bool view of :func:`cnn_manifold_map` (kept for callers; new
    code should use the manifold map)."""
    return {"conv1": False, "conv2": False, "fc1": True, "head": True}


def cnn_forward(params: dict, images: Array) -> Array:
    """images (B, H, W, C) -> logits (B, n_classes)."""
    x = images
    for w in (params["conv1"], params["conv2"]):
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jnp.tanh(x @ params["fc1"])
    return x @ params["head"]


def _per_class_ce(logits: Array, labels: Array, n_classes: int) -> Array:
    lp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(lp, labels[:, None], axis=-1)[:, 0]
    oh = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)
    counts = oh.sum(0)
    sums = (nll[:, None] * oh).sum(0)
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), nll.mean())


# ---------------------------------------------------------------------------
# Eq. 19/20 — fair classification over class losses
# ---------------------------------------------------------------------------


def fair_loss(params: dict, u: Array, batch: dict, *, n_classes: int,
              rho: float) -> Array:
    logits = cnn_forward(params, batch["images"])
    lc = _per_class_ce(logits, batch["labels"], n_classes)
    return jnp.dot(u, lc) - rho * jnp.sum(u ** 2)


def fair_y_star(params: dict, batches: dict, *, n_classes: int,
                rho: float) -> Array:
    def one(b):
        return _per_class_ce(cnn_forward(params, b["images"]), b["labels"],
                             n_classes)
    lc = jnp.mean(jax.vmap(one)(batches), axis=0)
    # max_u  u.l - rho||u||^2  over simplex  =  proj( l / (2 rho) )
    return project_simplex(lc / (2.0 * rho))


def make_fair_problem(params_template: dict, n_classes: int = 3,
                      rho: float = 1.0) -> MinimaxProblem:
    return MinimaxProblem(
        loss_fn=functools.partial(fair_loss, n_classes=n_classes, rho=rho),
        project_y=project_simplex,
        manifold_map=cnn_manifold_map(params_template),
        y_star=functools.partial(fair_y_star, n_classes=n_classes, rho=rho),
        name="fair-classification",
    )


# ---------------------------------------------------------------------------
# Eq. 21 — DRO over group weights
# ---------------------------------------------------------------------------


def dro_loss(params: dict, p: Array, batch: dict, *, n_groups: int) -> Array:
    logits = cnn_forward(params, batch["images"])
    # groups == class labels in the classification stream
    lg = _per_class_ce(logits, batch["labels"], n_groups)
    return jnp.dot(p, lg) - jnp.sum((p - 1.0 / n_groups) ** 2)


def dro_y_star(params: dict, batches: dict, *, n_groups: int) -> Array:
    def one(b):
        return _per_class_ce(cnn_forward(params, b["images"]), b["labels"],
                             n_groups)
    lg = jnp.mean(jax.vmap(one)(batches), axis=0)
    return project_simplex(1.0 / n_groups + lg / 2.0)


def make_dro_problem(params_template: dict, n_groups: int = 3) -> MinimaxProblem:
    return MinimaxProblem(
        loss_fn=functools.partial(dro_loss, n_groups=n_groups),
        project_y=project_simplex,
        manifold_map=cnn_manifold_map(params_template),
        y_star=functools.partial(dro_y_star, n_groups=n_groups),
        name="dro-classification",
    )
