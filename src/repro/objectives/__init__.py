from repro.objectives import fair, lm  # noqa: F401
