from repro.objectives import fair, lm, robust_pca  # noqa: F401
