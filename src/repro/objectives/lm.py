"""Group-DRO language-model objective — the paper's Eq. (21) form applied to
LM pretraining:

    min_{theta, St-leaves on St(d,r)}  max_{y in simplex_G}
        sum_g y_g * L_g(theta)  -  rho * ||y - 1/G||^2   (+ MoE aux loss)

strongly concave in y (coefficient rho), with the exact inner maximizer
y*(theta) = proj_simplex(1/G + L(theta) / (2 rho)) available in closed form
for the convergence metric M_t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.minimax import MinimaxProblem, project_simplex
from repro.geometry import manifold_map_from_paths
from repro.models import transformer as T

Array = jax.Array


def token_ce(logits: Array, targets: Array, impl: str = "gather",
             true_vocab: int = 0) -> Array:
    """Per-sequence mean CE.  logits (B,S,V) or (B,S,CB,V); targets match.

    impl="dot" computes the correct-class logit as a one-hot contraction
    over the vocab dim: with a model-sharded vocab this keeps the reduction
    local + a small all-reduce instead of gathering logits (§Perf).
    """
    lf = logits.astype(jnp.float32)
    if true_vocab and lf.shape[-1] > true_vocab:
        # padded unembedding rows (vocab_pad_to): exclude from the softmax
        v_pad = lf.shape[-1]
        mask = jnp.arange(v_pad) < true_vocab
        lf = jnp.where(mask, lf, -1e30)
    if impl == "dot":
        v = lf.shape[-1]
        m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(targets, v, dtype=lf.dtype)
        correct = jnp.sum(lf * onehot, axis=-1)
        nll = lse - correct
    else:
        lp = jax.nn.log_softmax(lf, axis=-1)
        nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    # mean over sequence (and codebooks)
    red = tuple(range(1, nll.ndim))
    return nll.mean(axis=red)                                   # (B,)


def group_losses(per_seq_loss: Array, group_ids: Array, n_groups: int) -> Array:
    """Mean loss per group; groups absent from the batch get the batch mean
    (so they neither attract nor repel the adversary)."""
    oh = jax.nn.one_hot(group_ids, n_groups, dtype=jnp.float32)   # (B,G)
    counts = oh.sum(0)
    sums = (per_seq_loss[:, None] * oh).sum(0)
    mean_all = per_seq_loss.mean()
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), mean_all)


def lm_minimax_loss(params, y: Array, batch: dict, cfg: ModelConfig) -> Array:
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    logits, aux, _ = T.forward(params, cfg, tokens[..., :-1, :]
                               if cfg.n_codebooks > 1 else tokens[:, :-1],
                               frontend_embeds=fe, mode="train")
    targets = tokens[..., 1:, :] if cfg.n_codebooks > 1 else tokens[:, 1:]
    per_seq = token_ce(logits, targets, impl=cfg.ce_impl,
                       true_vocab=cfg.vocab_size)
    lg = group_losses(per_seq, batch["group_ids"], cfg.n_groups)
    robust = jnp.dot(y, lg) - cfg.rho * jnp.sum(
        (y - 1.0 / cfg.n_groups) ** 2)
    return robust + aux


def lm_y_star(params, batches: dict, cfg: ModelConfig) -> Array:
    """Exact global inner maximizer at shared params (node-stacked batch)."""
    def one(b):
        tokens = b["tokens"]
        fe = b.get("frontend_embeds")
        logits, _, _ = T.forward(params, cfg, tokens[..., :-1, :]
                                 if cfg.n_codebooks > 1 else tokens[:, :-1],
                                 frontend_embeds=fe, mode="train")
        targets = tokens[..., 1:, :] if cfg.n_codebooks > 1 else tokens[:, 1:]
        return group_losses(token_ce(logits, targets, impl=cfg.ce_impl,
                                     true_vocab=cfg.vocab_size),
                            b["group_ids"], cfg.n_groups)
    lg = jnp.mean(jax.vmap(one)(batches), axis=0)
    return project_simplex(1.0 / cfg.n_groups + lg / (2.0 * cfg.rho))


def make_lm_problem(cfg: ModelConfig, params_template) -> MinimaxProblem:
    import re
    pattern = re.compile(cfg.manifold_policy)
    mmap = manifold_map_from_paths(
        params_template, lambda path: bool(pattern.search(path)),
        manifold=cfg.manifold)
    return MinimaxProblem(
        loss_fn=functools.partial(lm_minimax_loss, cfg=cfg),
        project_y=project_simplex,
        manifold_map=mmap,
        y_star=functools.partial(lm_y_star, cfg=cfg),
        name=f"group-dro-lm/{cfg.name}",
    )


def init_y(cfg: ModelConfig, n_nodes: int) -> Array:
    return jnp.full((n_nodes, cfg.n_groups), 1.0 / cfg.n_groups, jnp.float32)
