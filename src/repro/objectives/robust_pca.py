"""Robust PCA on the Grassmann manifold — a subspace minimax workload.

The paper motivates Riemannian minimax with robust dimensionality reduction;
this is that workload on Gr(d, r) (only the subspace matters, so the
geometry quotients out basis rotations — see
:class:`repro.geometry.grassmann.Grassmann`):

    min_{x in Gr(d,r)}  max_{y in simplex_m}
        sum_j y_j * res_j(x)  -  rho * ||y - 1/m||^2,
    res_j(x) = || z_j - x x^T z_j ||^2 / ||z_j||^2   (relative residual)

The adversary up-weights the samples the current subspace reconstructs
worst — a distributionally-robust PCA that cannot ignore outlier-heavy
sample groups.  It is linear in ``y`` with a rho-strongly-concave
regularizer, so the exact inner maximizer is closed form,

    y*(x) = proj_simplex( 1/m + res(x) / (2 rho) ),

which feeds the convergence metric M_t (Eq. 16) exactly like the paper's
fair-classification objective.

Each node holds ``m`` local samples (rows of ``batch["z"]``); heterogeneity
comes from node-specific sample draws and outlier fractions
(:func:`make_batches`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.minimax import MinimaxProblem, project_simplex

Array = jax.Array


def residuals(x: Array, z: Array) -> Array:
    """Per-sample relative reconstruction residual
    ``||z_j - x x^T z_j||^2 / ||z_j||^2``  in [0, 1].

    ``x`` (d, r) orthonormal; ``z`` (m, d).  Invariant to the choice of
    basis within span(x) — a true Grassmann objective — and to per-sample
    scale, which keeps the adversary's payoffs (and hence the stable
    step-size range for ``eta``) O(1) regardless of data magnitude.
    """
    proj = jnp.einsum("md,dr->mr", z, x)          # coordinates in the basis
    recon = jnp.einsum("mr,dr->md", proj, x)
    nrm = jnp.maximum(jnp.sum(z * z, axis=-1), 1e-12)
    return jnp.sum((z - recon) ** 2, axis=-1) / nrm


def robust_pca_loss(x: dict, y: Array, batch: dict, *, rho: float) -> Array:
    res = residuals(x["w"], batch["z"])
    m = res.shape[-1]
    return jnp.dot(y, res) - rho * jnp.sum((y - 1.0 / m) ** 2)


def robust_pca_y_star(x: dict, batches: dict, *, rho: float) -> Array:
    """Exact inner maximizer of the *global* objective at shared params
    (node-stacked batches, params broadcast)."""
    res = jnp.mean(jax.vmap(lambda b: residuals(x["w"], b["z"]))(batches),
                   axis=0)
    m = res.shape[-1]
    return project_simplex(1.0 / m + res / (2.0 * rho))


def make_robust_pca_problem(rho: float = 0.1) -> MinimaxProblem:
    return MinimaxProblem(
        loss_fn=functools.partial(robust_pca_loss, rho=rho),
        project_y=project_simplex,
        manifold_map={"w": "grassmann"},
        y_star=functools.partial(robust_pca_y_star, rho=rho),
        name="robust-pca",
    )


def make_batches(key: Array, n_nodes: int, m: int, d: int, r: int,
                 noise: float = 0.05, outlier_frac: float = 0.15,
                 outlier_scale: float = 3.0,
                 subspace: Optional[Array] = None) -> tuple[dict, Array]:
    """Node-heterogeneous spiked-subspace samples with outliers.

    Returns (batches, basis): ``batches["z"]`` is (n_nodes, m, d) — clean
    samples live near span(basis) (a random (d, r) orthonormal basis),
    while a per-node ``outlier_frac`` of rows is isotropic large-variance
    noise.  Robust PCA must recover span(basis) without being dragged by
    the outliers the adversary emphasizes.
    """
    kb, kc, ko, km = jax.random.split(key, 4)
    if subspace is None:
        subspace, _ = jnp.linalg.qr(jax.random.normal(kb, (d, r)))
    coeff = jax.random.normal(kc, (n_nodes, m, r))
    clean = jnp.einsum("nmr,dr->nmd", coeff, subspace)
    clean = clean + noise * jax.random.normal(km, (n_nodes, m, d))
    outliers = outlier_scale * jax.random.normal(ko, (n_nodes, m, d))
    is_out = (jax.random.uniform(jax.random.fold_in(key, 7), (n_nodes, m, 1))
              < outlier_frac)
    return {"z": jnp.where(is_out, outliers, clean)}, subspace


def init_y(n_nodes: int, m: int) -> Array:
    return jnp.full((n_nodes, m), 1.0 / m, jnp.float32)
