"""Manifold protocol + registry — the pluggable geometry layer.

Every geometry implements one small surface (the seven protocol methods
below) over arrays whose *last two* dims are the matrix dims (d, r);
leading dims (node axis, batched heads, ...) broadcast:

  * ``tangent_project(x, g)`` — orthogonal projection of ambient ``g``
    onto T_x M;
  * ``retract(x, u, kind=..., **kw)`` — map a tangent step back onto M
    (each geometry names its supported retractions);
  * ``project(a)`` — nearest-point (or representative) projection of an
    ambient point onto M;
  * ``consensus_mean(xs)`` — induced arithmetic mean over the leading
    node axis (paper Eq. 9 generalized: project the Euclidean mean);
  * ``dist(x, y)`` — a distance (geodesic where cheap, extrinsic else);
  * ``rand(key, d, r)`` — a uniform-ish random point;
  * ``check(x)`` — feasibility residual (0 on the manifold).

Optimizer hooks with sensible defaults (override only when the geometry
needs different math):

  * ``consensus_step(x, mx, alpha)`` — the consensus direction of the
    DRGDA x-update.  Riemannian default ``alpha * P_x(mx)`` (correct
    because ``P_x(x) = 0`` on the homogeneous geometries here);
    :class:`~repro.geometry.euclidean.Euclidean` overrides with the
    gradient-tracking form ``alpha * (mx - x)``.
  * ``feasible_init(x)`` — one-time projection of raw initializer output
    onto M (Stiefel/Grassmann use QR for exactness, see
    ``sharding.partition.project_params_to_manifold``).

Geometries register themselves under a name (``register``); ``get(name)``
resolves them, and :func:`as_manifold_map` normalizes the per-leaf
specification pytrees accepted by :class:`repro.core.minimax.MinimaxProblem`:
bools (the legacy ``stiefel_mask`` — True -> "stiefel", False ->
"euclidean"), registry names, or Manifold instances.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class Manifold:
    """Base class: shared defaults for the protocol (see module docstring).

    Subclasses must provide ``tangent_project``, ``retract``, ``project``,
    ``dist``, ``rand`` and ``check``; ``consensus_mean`` defaults to
    project-the-mean, which is the induced arithmetic mean for every
    geometry registered here.
    """

    #: registry name
    name: str = "abstract"
    #: retraction kinds ``retract`` accepts
    retractions: tuple[str, ...] = ()
    #: used when ``kind`` is None or names a retraction this geometry
    #: does not implement (a Product map shares one config string across
    #: heterogeneous leaves)
    default_retraction: str = ""
    #: name of the fused-kernel retraction, or None.  A fused retraction
    #: takes the *ambient* update direction and performs the tangent
    #: projection inside the kernel (see kernels/retract.py).
    fused_retraction: Optional[str] = None
    #: True when points must be tall matrices (d >= r) — orthonormal-column
    #: geometries; norm-constraint geometries accept any (d, r)
    requires_tall: bool = False

    # -- protocol ----------------------------------------------------------
    def tangent_project(self, x: Array, g: Array) -> Array:
        raise NotImplementedError

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                **kw) -> Array:
        raise NotImplementedError

    def project(self, a: Array, method: str = "ns") -> Array:
        raise NotImplementedError

    def consensus_mean(self, xs: Array, method: str = "ns") -> Array:
        """IAM over the leading axis (Eq. 9): project( mean_i xs_i )."""
        return self.project(jnp.mean(xs, axis=0), method=method)

    def dist(self, x: Array, y: Array) -> Array:
        raise NotImplementedError

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        raise NotImplementedError

    def check(self, x: Array) -> Array:
        """Feasibility residual, 0 on the manifold (batched over leading
        dims like the per-geometry error norms)."""
        raise NotImplementedError

    # -- optimizer hooks ---------------------------------------------------
    def resolve_retraction(self, kind: Optional[str]) -> str:
        """Map a (possibly foreign) retraction name onto one this geometry
        implements — Product maps share one config string across leaves."""
        if kind in self.retractions:
            return kind
        return self.default_retraction

    def consensus_step(self, x: Array, mx: Array, alpha: float) -> Array:
        """Tangent consensus direction of the DRGDA x-update (Alg. 1
        step 4): ``alpha * P_x([W^k x]_i)``."""
        return alpha * self.tangent_project(x, mx)

    def descent_update(self, x: Array, mx: Array, u: Array, *, alpha: float,
                       beta: float, kind: Optional[str] = None, **kw) -> Array:
        """One DRGDA x-update on this leaf:
        ``R_x( alpha P_x(mx) - beta P_x(u) )`` — overridden by Euclidean to
        keep the historical flat-space expression bit-for-bit."""
        cons = self.consensus_step(x, mx, alpha)
        w = self.tangent_project(x, u)
        return self.retract(x, cons - beta * w, kind, **kw)

    def feasible_init(self, x: Array) -> Array:
        """Map raw initializer output to a feasible starting point."""
        return self.project(x)

    def riemannian_grad(self, x: Array, egrad: Array) -> Array:
        return self.tangent_project(x, egrad)

    def __repr__(self):
        return f"{type(self).__name__}()"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

REGISTRY: dict[str, Manifold] = {}


def register(manifold: Manifold) -> Manifold:
    """Register a (stateless, shared) manifold instance under its name."""
    REGISTRY[manifold.name] = manifold
    return manifold


def get(name: str) -> Manifold:
    try:
        return REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown manifold {name!r}; registered: {sorted(REGISTRY)}"
        ) from None


def known_retractions() -> set[str]:
    """Union of retraction names over all registered geometries."""
    return {k for m in REGISTRY.values() for k in m.retractions}


def check_retraction_name(kind: str) -> str:
    """Raise on a retraction name NO registered geometry implements.

    ``resolve_retraction`` intentionally falls back per leaf (one config
    string drives mixed Product maps), so typos would otherwise silently
    measure each leaf's default — validate the name globally instead.
    """
    known = known_retractions()
    if kind not in known:
        raise ValueError(
            f"unknown retraction {kind!r}; known: {sorted(known)}")
    return kind


# ---------------------------------------------------------------------------
# per-leaf manifold maps
# ---------------------------------------------------------------------------


# once-per-process flag for the legacy-bool deprecation below; tests reset
# it to re-assert the warning fires exactly once
_warned_stiefel_mask = False


def _warn_stiefel_mask() -> None:
    global _warned_stiefel_mask
    if _warned_stiefel_mask:
        return
    _warned_stiefel_mask = True
    import warnings
    warnings.warn(
        "stiefel_mask bool pytrees are deprecated; pass a manifold_map "
        "(registry-name strings or Manifold instances) instead",
        DeprecationWarning, stacklevel=3)


def _as_manifold(spec) -> Manifold:
    if isinstance(spec, Manifold):
        return spec
    if isinstance(spec, str):
        return get(spec)
    if isinstance(spec, bool):
        # legacy stiefel_mask bools: True -> Stiefel, False -> Euclidean
        _warn_stiefel_mask()
        return get("stiefel") if spec else get("euclidean")
    if isinstance(spec, int) or spec is None:
        return get("stiefel") if spec else get("euclidean")
    raise TypeError(f"cannot interpret {spec!r} as a manifold")


def as_manifold_map(map_or_mask: PyTree) -> PyTree:
    """Normalize a per-leaf geometry spec pytree to Manifold instances.

    Accepts the legacy bool ``stiefel_mask`` pytrees, registry-name strings,
    Manifold instances, or any mixture.
    """
    return jax.tree.map(_as_manifold, map_or_mask,
                        is_leaf=lambda s: isinstance(s, Manifold))


def bool_mask(manifold_map: PyTree) -> PyTree:
    """Back-derive the legacy bool mask: True where the leaf is Stiefel."""
    return jax.tree.map(lambda m: m.name == "stiefel", manifold_map,
                        is_leaf=lambda s: isinstance(s, Manifold))


def manifold_map_from_paths(params: PyTree, predicate: Callable[[str], bool],
                            manifold: str | Manifold = "stiefel") -> PyTree:
    """Per-leaf manifold map by matching '/'-joined key paths.

    Matched leaves get ``manifold`` (name or instance) when they are
    matrix-shaped (ndim >= 2; additionally tall, d >= r, for geometries
    with ``requires_tall`` — orthonormal columns need it, norm constraints
    don't); everything else stays Euclidean.
    """
    m = _as_manifold(manifold)
    eu = get("euclidean")
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    vals = []
    for path, leaf in flat:
        name = "/".join(_key_str(k) for k in path)
        ok = bool(predicate(name)) and leaf.ndim >= 2 \
            and (not m.requires_tall or leaf.shape[-2] >= leaf.shape[-1])
        vals.append(m if ok else eu)
    return jax.tree.unflatten(treedef, vals)


def _key_str(k) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)
