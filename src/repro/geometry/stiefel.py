"""Stiefel manifold St(d, r) = {x in R^{d x r} : x^T x = I_r}.

The paper's geometry (Wu, Hu & Huang, AAAI'23), migrated here from
``repro.core.manifolds`` with

  * tangent projection  P_{T_x}(g) = g - x * sym(x^T g)          (Eq. 3)
  * polar retraction    R_x(u)     = (x + u)(I_r + u^T u)^{-1/2}  (Lemma 1)
  * QR retraction       qf(x + u)  with sign fix
  * Cayley retraction   (I - W/2)^{-1}(I + W/2) x with the Wen--Yin skew
    W = W_hat - W_hat^T, W_hat = (I - x x^T/2) u x^T, solved by matmul-only
    CG / Neumann iterations (see :func:`retract_cayley`)
  * induced arithmetic mean (IAM)  x_hat = P_St(mean_i x_i)       (Eq. 9)

All functions operate on arrays whose *last two* dims are (d, r); leading
dims (node axis, batched heads, ...) broadcast.  TPU adaptation: the polar
factors are computed with Newton--Schulz iterations (matmul-only, maps to
the MXU) instead of SVD/eigh; an eigh-based oracle is kept for tests and
for the CPU-exactness path; the fused "polar_fused" retraction dispatches
to the Pallas kernel in :mod:`repro.kernels.retract`.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.geometry.base import Manifold, register

Array = jax.Array

# ---------------------------------------------------------------------------
# basic tangent-space ops
# ---------------------------------------------------------------------------


def sym(a: Array) -> Array:
    """Symmetric part (over the last two dims)."""
    return 0.5 * (a + jnp.swapaxes(a, -1, -2))


def tangent_project(x: Array, g: Array) -> Array:
    """Orthogonal projection of ambient ``g`` onto T_x St(d, r)  (Eq. 3).

    P_{T_x}(g) = g - x sym(x^T g).  Note P_{T_x}(x) = 0.
    """
    xtg = jnp.einsum("...dr,...ds->...rs", x, g)
    return g - jnp.einsum("...dr,...rs->...ds", x, sym(xtg))


def is_tangent(x: Array, u: Array, atol: float = 1e-5) -> Array:
    """Check u in T_x M:  x^T u + u^T x = 0."""
    a = jnp.einsum("...dr,...ds->...rs", x, u)
    return jnp.max(jnp.abs(a + jnp.swapaxes(a, -1, -2))) < atol


def stiefel_error(x: Array) -> Array:
    """|| x^T x - I ||_F  (feasibility residual)."""
    r = x.shape[-1]
    xtx = jnp.einsum("...dr,...ds->...rs", x, x)
    return jnp.linalg.norm(xtx - jnp.eye(r, dtype=x.dtype), axis=(-2, -1))


# ---------------------------------------------------------------------------
# matrix inverse square root: Newton--Schulz (TPU) and eigh (oracle)
# ---------------------------------------------------------------------------


def _invsqrt_eigh(a: Array) -> Array:
    """Exact (I-free) inverse square root of an SPD matrix via eigh."""
    w, v = jnp.linalg.eigh(a)
    w = jnp.maximum(w, 1e-12)
    return jnp.einsum("...ir,...r,...jr->...ij", v, jax.lax.rsqrt(w), v)


def _invsqrt_newton_schulz(a: Array, iters: int = 20) -> Array:
    """Inverse square root of SPD ``a`` via the coupled Newton--Schulz
    (Denman--Beavers variant with Y/Z coupling) iteration.

    Matmul-only => maps onto the TPU MXU; converges quadratically provided
    ||I - a/c|| < 1 after the trace-based scaling below.  For the polar
    retraction, ``a = I + u^T u`` is SPD with eigenvalues >= 1, and ``u`` is a
    (step-size-scaled) tangent update, so conditioning is benign.
    """
    r = a.shape[-1]
    eye = jnp.eye(r, dtype=a.dtype)
    # scale so the spectrum lies in (0, 1]: the induced inf-norm (max abs
    # row sum) upper-bounds the spectral radius of the symmetric ``a``;
    # quadratic NS convergence then needs ~log2(log(eps)/log(1-1/cond))
    # iterations — 12 covers cond ~ 1e2 at fp32 accuracy.
    c = jnp.max(jnp.sum(jnp.abs(a), axis=-1), axis=-1)[..., None, None] + 1e-6
    y = a / c
    z = jnp.broadcast_to(eye, a.shape)

    def body(_, yz):
        y, z = yz
        t = 0.5 * (3.0 * eye - z @ y)
        return (y @ t, t @ z)

    y, z = jax.lax.fori_loop(0, iters, body, (y, z))
    # z ~ (a/c)^{-1/2}  =>  a^{-1/2} = z / sqrt(c)
    return z * jax.lax.rsqrt(c)


def invsqrt_spd(a: Array, method: Literal["ns", "eigh"] = "ns") -> Array:
    if method == "eigh":
        return _invsqrt_eigh(a)
    return _invsqrt_newton_schulz(a)


# ---------------------------------------------------------------------------
# retractions
# ---------------------------------------------------------------------------


def retract_polar(x: Array, u: Array, method: Literal["ns", "eigh"] = "ns") -> Array:
    """Polar retraction R_x(u) = (x+u)(I + u^T u)^{-1/2} (Lemma 1).

    Valid for u in T_x M (then (x+u)^T (x+u) = I + u^T u).  Non-expansive
    towards the manifold (Eq. 7), second-order bounded (Eq. 6).
    """
    r = u.shape[-1]
    utu = jnp.einsum("...dr,...ds->...rs", u, u)
    a = jnp.eye(r, dtype=u.dtype) + utu
    return jnp.einsum("...dr,...rs->...ds", x + u, invsqrt_spd(a, method))


def retract_qr(x: Array, u: Array) -> Array:
    """QR retraction: qf(x + u) with sign fix so R_x(0) = x."""
    q, rr = jnp.linalg.qr(x + u)
    d = jnp.sign(jnp.diagonal(rr, axis1=-2, axis2=-1))
    d = jnp.where(d == 0, 1.0, d)
    return q * d[..., None, :]


def retract_cayley(x: Array, u: Array, iters: int = 12,
                   solver: Literal["cg", "neumann"] = "cg") -> Array:
    """Cayley retraction (Wen & Yin 2013):

        R_x(u) = (I - W/2)^{-1} (I + W/2) x,
        W = W_hat - W_hat^T,   W_hat = (I - x x^T / 2) u x^T.

    ``W`` is skew-symmetric by construction, so the Cayley factor is exactly
    orthogonal and R_x(u) lands on St(d, r) for ANY ``u``; for tangent ``u``
    the half-projector makes ``W x = u`` exactly (the cross terms cancel via
    x^T u + u^T x = 0), giving true first-order agreement
    R_x(tu) = x + tu + O(t^2).  Instead of forming or factorizing the (d, d)
    system, the solve is iterative with ``W`` applied in its low-rank form
    (rank <= 2r: tall (d, r) matmuls against (r, r) intermediates) — the
    same matmul-only MXU profile as the Newton--Schulz polar path.

    * ``solver="cg"`` (default): CG on the normal equations.  Because
      (I - W/2)^T = I + W/2, they read  (I - W^2/4) z = (I + W + W^2/4) x
      with the SPD operator I - W^2/4 = I + W^T W / 4 (eigenvalues in
      [1, 1 + ||W||^2/4]) — CG converges for ANY step size, and the benign
      conditioning at step-size-scaled ``u`` makes ~12 iterations cover
      fp32 accuracy.
    * ``solver="neumann"``: the plain fixed point  z <- (I + W/2)x + (W/2)z,
      one ``W`` apply per iteration, but geometric convergence requires
      ||W|| < 2 (roughly ||u|| < 1).
    """
    xtu = jnp.einsum("...dr,...ds->...rs", x, u)

    def wv(v: Array) -> Array:
        # W v = u (x^T v) - x [ u^T v + 0.5 (x^T u)(x^T v)
        #                               - 0.5 (x^T u)^T (x^T v) ]
        xtv = jnp.einsum("...dr,...ds->...rs", x, v)
        utv = jnp.einsum("...dr,...ds->...rs", u, v)
        inner = utv + 0.5 * (jnp.einsum("...rs,...st->...rt", xtu, xtv)
                             - jnp.einsum("...sr,...st->...rt", xtu, xtv))
        return (jnp.einsum("...dr,...rs->...ds", u, xtv)
                - jnp.einsum("...dr,...rs->...ds", x, inner))

    if solver == "neumann":
        b = x + 0.5 * wv(x)

        def body(_, z):
            return b + 0.5 * wv(z)

        return jax.lax.fori_loop(0, iters, body, b)

    def a_op(v: Array) -> Array:               # (I - W^2/4) v, SPD
        return v - 0.25 * wv(wv(v))

    def dot(a: Array, b: Array) -> Array:
        return jnp.sum(a * b, axis=(-2, -1), keepdims=True)

    wx = wv(x)
    rhs = x + wx + 0.25 * wv(wx)               # (I + W + W^2/4) x
    z = x                                      # z ~ x for small steps
    r = rhs - a_op(z)
    p = r
    rr = dot(r, r)

    def body(_, zrp):
        z, r, p, rr = zrp
        ap = a_op(p)
        # guarded divisions: converged (r = 0) batch elements stay fixed
        alpha = rr / jnp.maximum(dot(p, ap), 1e-30)
        z = z + alpha * p
        r = r - alpha * ap
        rr_new = dot(r, r)
        beta = rr_new / jnp.maximum(rr, 1e-30)
        return z, r, r + beta * p, rr_new

    z, _, _, _ = jax.lax.fori_loop(0, iters, body, (z, r, p, rr))
    return z


# ---------------------------------------------------------------------------
# projection onto the manifold (polar factor) + IAM
# ---------------------------------------------------------------------------


def project_stiefel(a: Array, method: Literal["ns", "eigh"] = "ns") -> Array:
    """P_St(a): nearest Stiefel point = polar factor U of a = U P.

    Computed as a (a^T a)^{-1/2}.  ``a`` must have full column rank (true for
    averages of nearby Stiefel points, the only use in the algorithm).
    """
    ata = jnp.einsum("...dr,...ds->...rs", a, a)
    return jnp.einsum("...dr,...rs->...ds", a, invsqrt_spd(ata, method))


def induced_arithmetic_mean(xs: Array, method: Literal["ns", "eigh"] = "ns") -> Array:
    """IAM over the leading axis (Eq. 9): P_St( (1/n) sum_i x_i )."""
    return project_stiefel(jnp.mean(xs, axis=0), method)


def consensus_error(xs: Array) -> Array:
    """(1/n) || x - 1 (x_hat) ||^2 style residual (Eq. 10), returned as the
    mean squared distance of the stacked replicas to their IAM."""
    xhat = induced_arithmetic_mean(xs)
    return jnp.mean(jnp.sum((xs - xhat) ** 2, axis=(-2, -1)))


# ---------------------------------------------------------------------------
# random points / misc
# ---------------------------------------------------------------------------


def random_stiefel(key: jax.Array, d: int, r: int, batch: tuple[int, ...] = (),
                   dtype=jnp.float32) -> Array:
    a = jax.random.normal(key, (*batch, d, r), dtype=dtype)
    q, _ = jnp.linalg.qr(a)
    return q


def riemannian_grad(x: Array, egrad: Array) -> Array:
    """Riemannian gradient = tangent projection of the Euclidean gradient."""
    return tangent_project(x, egrad)


# ---------------------------------------------------------------------------
# the registered geometry
# ---------------------------------------------------------------------------


class Stiefel(Manifold):
    """St(d, r) over the last two dims; the paper's default geometry."""

    name = "stiefel"
    retractions = ("polar", "qr", "cayley", "polar_fused")
    default_retraction = "polar"
    fused_retraction = "polar_fused"
    requires_tall = True

    def tangent_project(self, x: Array, g: Array) -> Array:
        return tangent_project(x, g)

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                *, method: str = "ns", iters: Optional[int] = None,
                solver: str = "cg", **kw) -> Array:
        kind = kind or self.default_retraction
        if kind == "polar":
            return retract_polar(x, u, method=method)
        if kind == "qr":
            return retract_qr(x, u)
        if kind == "cayley":
            return retract_cayley(x, u, solver=solver,
                                  **({"iters": iters} if iters else {}))
        if kind == "polar_fused":
            # fused Pallas path: ``u`` is the AMBIENT update direction; the
            # kernel performs tangent projection + Gram + NS + apply in one
            # VMEM-resident pass (ref oracle on non-TPU backends).
            from repro.kernels import ops
            return ops.fused_retract(x, u, **kw)
        raise ValueError(f"unknown retraction {kind!r}")

    def project(self, a: Array, method: str = "ns") -> Array:
        return project_stiefel(a, method)

    def dist(self, x: Array, y: Array) -> Array:
        """Extrinsic (embedded-Frobenius) distance — what the paper's
        consensus/metric expressions use."""
        return jnp.linalg.norm(x - y, axis=(-2, -1))

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        return random_stiefel(key, d, r, batch, dtype)

    def check(self, x: Array) -> Array:
        return stiefel_error(x)

    def feasible_init(self, x: Array) -> Array:
        # QR orthonormalization: exact feasibility regardless of the raw
        # initializer's conditioning (polar/NS loses digits when x^T x has
        # tiny eigenvalues); the algorithm only needs x0 ON the manifold.
        return retract_qr(jnp.zeros_like(x), x)


STIEFEL = register(Stiefel())


@functools.partial(jax.jit, static_argnames=("kind",))
def rgd_step(x: Array, egrad: Array, lr: float, kind: str = "polar") -> Array:
    """Single-node Riemannian gradient-descent step (Eq. 4) — used by tests
    and by the centralized reference implementations."""
    return STIEFEL.retract(x, -lr * tangent_project(x, egrad), kind)
