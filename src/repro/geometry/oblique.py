"""Oblique manifold OB(d, r) and the unit sphere — norm constraints.

``Oblique`` is the product of r unit spheres S^{d-1}, one per *column* of
the (d, r) leaf: x^T x has unit diagonal.  This is exactly the constraint
set of column-normalized DNN layers (weight-normalized linear maps,
normalized embedding directions), and every operation is a cheap row-wise
(VPU, not MXU) op — no (r, r) Gram algebra, no inverse square roots.

``Sphere`` treats the whole (d, r) block as one unit-Frobenius-norm vector
(a fully-normalized layer); same formulas with the reduction over both
trailing dims.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.geometry.base import Manifold, register

Array = jax.Array

_EPS = 1e-12


def _colnorm(x: Array) -> Array:
    return jnp.sqrt(jnp.sum(x * x, axis=-2, keepdims=True))


class Oblique(Manifold):
    """Unit-norm columns over the last two dims."""

    name = "oblique"
    retractions = ("normalize",)
    default_retraction = "normalize"

    def tangent_project(self, x: Array, g: Array) -> Array:
        # per column: g_c - x_c <x_c, g_c>   (x_c unit)
        return g - x * jnp.sum(x * g, axis=-2, keepdims=True)

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                **kw) -> Array:
        return self.project(x + u)

    def project(self, a: Array, method: str = "ns") -> Array:
        return a / jnp.maximum(_colnorm(a), _EPS)

    def dist(self, x: Array, y: Array) -> Array:
        """Geodesic: sqrt(sum of squared per-column great-circle angles)."""
        cos = jnp.clip(jnp.sum(x * y, axis=-2), -1.0, 1.0)
        return jnp.linalg.norm(jnp.arccos(cos), axis=-1)

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        return self.project(jax.random.normal(key, (*batch, d, r), dtype))

    def check(self, x: Array) -> Array:
        return jnp.linalg.norm(_colnorm(x)[..., 0, :] - 1.0, axis=-1)


class Sphere(Manifold):
    """Unit Frobenius norm over the whole (d, r) block."""

    name = "sphere"
    retractions = ("normalize",)
    default_retraction = "normalize"

    def tangent_project(self, x: Array, g: Array) -> Array:
        inner = jnp.sum(x * g, axis=(-2, -1), keepdims=True)
        return g - x * inner

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                **kw) -> Array:
        return self.project(x + u)

    def project(self, a: Array, method: str = "ns") -> Array:
        nrm = jnp.sqrt(jnp.sum(a * a, axis=(-2, -1), keepdims=True))
        return a / jnp.maximum(nrm, _EPS)

    def dist(self, x: Array, y: Array) -> Array:
        cos = jnp.clip(jnp.sum(x * y, axis=(-2, -1)), -1.0, 1.0)
        return jnp.arccos(cos)

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        return self.project(jax.random.normal(key, (*batch, d, r), dtype))

    def check(self, x: Array) -> Array:
        return jnp.abs(jnp.sqrt(jnp.sum(x * x, axis=(-2, -1))) - 1.0)


OBLIQUE = register(Oblique())
SPHERE = register(Sphere())
