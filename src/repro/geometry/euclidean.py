"""Euclidean "manifold" — the identity geometry for unconstrained leaves.

Makes the optimizer math uniformly geometry-generic: embeddings, routers,
conv kernels and other unconstrained parameters run through the same
per-leaf code path as Stiefel/Grassmann/oblique leaves, with every
operation collapsing to its trivial form.  The one non-trivial override is
``consensus_step``: the generic Riemannian consensus ``alpha * P_x(mx)``
relies on ``P_x(x) = 0``, which does not hold in flat space, so the
Euclidean specialization is the gradient-tracking form
``x + alpha ([W x]_i - x)`` (GT-GDA's update; classic consensus at
``alpha = 1``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.geometry.base import Manifold, register

Array = jax.Array


class Euclidean(Manifold):
    name = "euclidean"
    retractions = ("add",)
    default_retraction = "add"

    def tangent_project(self, x: Array, g: Array) -> Array:
        return g

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                **kw) -> Array:
        return x + u

    def project(self, a: Array, method: str = "ns") -> Array:
        return a

    def dist(self, x: Array, y: Array) -> Array:
        return jnp.sqrt(jnp.sum((x - y) ** 2,
                                axis=tuple(range(-min(x.ndim, 2), 0))))

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        return jax.random.normal(key, (*batch, d, r), dtype=dtype)

    def check(self, x: Array) -> Array:
        return jnp.zeros(x.shape[:-2] if x.ndim >= 2 else ())

    def consensus_step(self, x: Array, mx: Array, alpha: float) -> Array:
        return alpha * (mx - x)

    def descent_update(self, x: Array, mx: Array, u: Array, *, alpha: float,
                       beta: float, kind=None, **kw) -> Array:
        # written exactly as GT-GDA's x + alpha([Wx]_i - x) - beta u — the
        # summation order matters for bit-compatibility with the
        # pre-geometry optimizer
        return x + alpha * (mx - x) - beta * u

    def feasible_init(self, x: Array) -> Array:
        return x


EUCLIDEAN = register(Euclidean())
