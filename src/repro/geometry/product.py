"""Product manifold over mixed pytrees.

A :class:`Product` wraps a per-leaf manifold map (see
:func:`repro.geometry.base.as_manifold_map`) and implements the whole
Manifold protocol treewise, so code that wants one geometry object over a
parameter pytree — mixed Stiefel attention weights, oblique embeddings,
Euclidean gates — gets the same seven-method surface as a single leaf.

Retraction kinds are resolved *per leaf* (``resolve_retraction``), so one
config string like ``"cayley"`` applies where supported and falls back to
each leaf's default elsewhere.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.geometry.base import Manifold, as_manifold_map

Array = jax.Array
PyTree = Any


class Product(Manifold):
    """Treewise product of per-leaf manifolds."""

    name = "product"

    def __init__(self, manifold_map: PyTree):
        self.map = as_manifold_map(manifold_map)

    def _zip(self, fn, *trees):
        return jax.tree.map(fn, self.map, *trees)

    # -- protocol ----------------------------------------------------------
    def tangent_project(self, x: PyTree, g: PyTree) -> PyTree:
        return self._zip(lambda m, xi, gi: m.tangent_project(xi, gi), x, g)

    def retract(self, x: PyTree, u: PyTree, kind: Optional[str] = None,
                **kw) -> PyTree:
        return self._zip(
            lambda m, xi, ui: m.retract(xi, ui, m.resolve_retraction(kind),
                                        **kw), x, u)

    def project(self, a: PyTree, method: str = "ns") -> PyTree:
        return self._zip(lambda m, ai: m.project(ai, method=method), a)

    def consensus_mean(self, xs: PyTree, method: str = "ns") -> PyTree:
        return self._zip(lambda m, xi: m.consensus_mean(xi, method=method), xs)

    def dist(self, x: PyTree, y: PyTree) -> Array:
        sq = self._zip(lambda m, xi, yi: jnp.sum(m.dist(xi, yi) ** 2), x, y)
        return jnp.sqrt(sum(jax.tree.leaves(sq)))

    def rand(self, key: Array, like: PyTree, dtype=jnp.float32) -> PyTree:
        """Random point with the shapes of ``like`` (arrays or ShapeDtype).

        Signature differs from the leaf protocol: shapes come from a
        template pytree, not (d, r) ints.
        """
        leaves = jax.tree.leaves(like)
        keys = jax.tree.unflatten(jax.tree.structure(like),
                                  list(jax.random.split(key, len(leaves))))

        def one(m: Manifold, k, l):
            d, r = l.shape[-2], l.shape[-1]
            return m.rand(k, d, r, batch=tuple(l.shape[:-2]), dtype=dtype)

        return self._zip(one, keys, like)

    def check(self, x: PyTree) -> Array:
        errs = jax.tree.leaves(
            self._zip(lambda m, xi: jnp.max(m.check(xi)), x))
        return jnp.max(jnp.stack(errs)) if errs else jnp.zeros(())

    # -- optimizer hooks ---------------------------------------------------
    def consensus_step(self, x: PyTree, mx: PyTree, alpha: float) -> PyTree:
        return self._zip(lambda m, xi, mi: m.consensus_step(xi, mi, alpha),
                         x, mx)

    def feasible_init(self, x: PyTree) -> PyTree:
        return self._zip(lambda m, xi: m.feasible_init(xi), x)

    def __repr__(self):
        names = sorted({m.name for m in jax.tree.leaves(self.map)
                        if isinstance(m, Manifold)})
        return f"Product({'+'.join(names)})"
