"""repro.geometry — pluggable manifold subsystem.

Registered geometries (``REGISTRY``): ``stiefel`` (the paper's default),
``grassmann``, ``oblique``, ``sphere``, ``euclidean``; ``Product`` composes
them over mixed pytrees.  See ``base.py`` for the seven-method protocol and
the README's "geometry layer" section for how to add a manifold.
"""
from repro.geometry.base import (Manifold, REGISTRY, as_manifold_map,  # noqa: F401
                                 bool_mask, get, manifold_map_from_paths,
                                 register)
from repro.geometry.euclidean import EUCLIDEAN, Euclidean  # noqa: F401
from repro.geometry.stiefel import STIEFEL, Stiefel  # noqa: F401
from repro.geometry.grassmann import GRASSMANN, Grassmann  # noqa: F401
from repro.geometry.oblique import OBLIQUE, SPHERE, Oblique, Sphere  # noqa: F401
from repro.geometry.product import Product  # noqa: F401
