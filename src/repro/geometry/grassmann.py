"""Grassmann manifold Gr(d, r) — r-dimensional subspaces of R^d.

Points are represented by Stiefel matrices (orthonormal bases); two
representatives spanning the same subspace are the same Grassmann point.
The horizontal space at ``x`` (the tangent space of the quotient) is

    H_x = { u : x^T u = 0 },      P_{H_x}(g) = (I - x x^T) g = g - x (x^T g)

— note NO symmetrization, unlike Stiefel's Eq. 3: vertical rotations
x Ω (Ω skew) move the representative without moving the subspace, and the
horizontal projection removes them entirely.  Retractions re-orthonormalize
``x + u`` (polar / QR), returning a representative of the retracted
subspace; the IAM projects the Euclidean mean of representatives — for
nearby subspaces this is the standard extrinsic (chordal) mean.

Enables subspace workloads — robust PCA minimax
(:mod:`repro.objectives.robust_pca`) — where only span(x), not the basis,
matters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.geometry.base import Manifold, register
from repro.geometry import stiefel as S

Array = jax.Array


def horizontal_project(x: Array, g: Array) -> Array:
    """P_{H_x}(g) = g - x (x^T g): projection onto the horizontal space."""
    xtg = jnp.einsum("...dr,...ds->...rs", x, g)
    return g - jnp.einsum("...dr,...rs->...ds", x, xtg)


def principal_angles(x: Array, y: Array) -> Array:
    """Principal angles between span(x) and span(y) (ascending, in [0, pi/2])."""
    s = jnp.linalg.svd(jnp.einsum("...dr,...ds->...rs", x, y),
                       compute_uv=False)
    return jnp.arccos(jnp.clip(s, -1.0, 1.0))[..., ::-1]


class Grassmann(Manifold):
    """Gr(d, r) via orthonormal representatives (last two dims)."""

    name = "grassmann"
    retractions = ("polar", "qr")
    default_retraction = "polar"
    requires_tall = True

    def tangent_project(self, x: Array, g: Array) -> Array:
        return horizontal_project(x, g)

    def retract(self, x: Array, u: Array, kind: Optional[str] = None,
                *, method: str = "ns", **kw) -> Array:
        kind = kind or self.default_retraction
        if kind == "polar":
            # (x+u)^T (x+u) = I + u^T u for horizontal u, same polar factor
            # identity as Stiefel's Lemma 1
            return S.retract_polar(x, u, method=method)
        if kind == "qr":
            return S.retract_qr(x, u)
        raise ValueError(f"unknown retraction {kind!r}")

    def project(self, a: Array, method: str = "ns") -> Array:
        # polar factor: an orthonormal basis of the dominant subspace of a
        return S.project_stiefel(a, method)

    def dist(self, x: Array, y: Array) -> Array:
        """Geodesic (arc-length) distance: || principal angles ||_2."""
        return jnp.linalg.norm(principal_angles(x, y), axis=-1)

    def rand(self, key: Array, d: int, r: int, batch: tuple[int, ...] = (),
             dtype=jnp.float32) -> Array:
        return S.random_stiefel(key, d, r, batch, dtype)

    def check(self, x: Array) -> Array:
        # representative feasibility: orthonormal basis
        return S.stiefel_error(x)

    def feasible_init(self, x: Array) -> Array:
        return S.retract_qr(jnp.zeros_like(x), x)


GRASSMANN = register(Grassmann())
