"""Roofline table builder: reads experiments/dryrun/*.json (written by
``python -m repro.launch.dryrun``) and emits the §Roofline table rows —
three terms, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful fraction."""
from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_records(d: str = DRYRUN_DIR) -> list[dict]:
    if not os.path.isdir(d):
        return []
    recs = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                recs.append(json.load(fh))
    return recs


def table(recs: list[dict]) -> list[dict]:
    rows = []
    for r in recs:
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "variant": r.get("variant", ""),
            "chips": r["chips"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful_fraction": r.get("useful_fraction"),
            "compile_s": r.get("compile_s"),
        })
    return rows


def markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | variant | compute_s | memory_s | "
           "collective_s | dominant | useful_frac |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        uf = r["useful_fraction"]
        body += (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                 f"{r['variant'] or '-'} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | "
                 f"{uf:.3f} |\n" if uf is not None else
                 f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                 f"{r['variant'] or '-'} | {r['compute_s']:.3e} | "
                 f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
                 f"**{r['dominant']}** | - |\n")
    return hdr + body


def run() -> dict:
    recs = load_records()
    rows = table(recs)
    dominants = {}
    for r in rows:
        dominants[r["dominant"]] = dominants.get(r["dominant"], 0) + 1
    return {"n_records": len(rows), "dominant_histogram": dominants,
            "rows": rows}


if __name__ == "__main__":
    print(markdown(run()["rows"]))
