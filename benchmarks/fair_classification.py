"""Paper Figures 1 & 2 — orthonormal fair classification (Eq. 19/20).

Deterministic setting (Fig. 1): DRGDA vs GT-GDA on full local datasets.
Stochastic setting  (Fig. 2): DRSGDA vs GNSD-A / DM-HSGD / GT-SRVR on
minibatches.  n = 20 worker nodes, ring topology — the paper's setup; data
is the deterministic synthetic classification stream (offline container)
with the same 3-class group structure and per-node heterogeneity.

Outputs loss/metric curves per method + derived summary (final loss, final
M_t, steps-to-threshold).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import OPTIMIZERS
from repro.core.baselines import HSGDHyper, SRVRHyper
from repro.core.gda import GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.metric import convergence_metric
from repro.data.synthetic import ClassificationStream
from repro.objectives import fair

N_NODES = 20
RHO = 1.0


def _setup(seed=0, batch_per_node=32):
    stream = ClassificationStream(n_nodes=N_NODES,
                                  batch_per_node=batch_per_node, seed=seed)
    params = fair.init_cnn(jax.random.PRNGKey(seed),
                           image_hw=stream.image_hw)
    problem = fair.make_fair_problem(params, rho=RHO)
    x0 = broadcast_to_nodes(params, N_NODES)
    y0 = jnp.full((N_NODES, 3), 1.0 / 3.0)
    return stream, problem, x0, y0


def _to_jax(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def run_method(name: str, steps: int, deterministic: bool, seed: int = 0,
               hyper=None, eval_every: int = 10) -> dict:
    stream, problem, x0, y0 = _setup(seed)
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, k_steps=1)
    cls = OPTIMIZERS[name]
    if name == "dm-hsgd":
        opt = cls(problem, spec, hyper or HSGDHyper(beta=0.05, eta=0.2, bx=0.1))
    elif name == "gt-srvr":
        opt = cls(problem, spec, hyper or SRVRHyper(beta=0.05, eta=0.2, q=16))
    else:
        opt = cls(problem, spec,
                  hyper or GDAHyper(alpha=0.5, beta=0.05, eta=0.2))

    full = _to_jax(stream.full(n_batches=4))
    state = opt.init(x0, y0, full if deterministic else _to_jax(stream.batch(0)))
    if name == "gt-srvr":
        step_fn, anchor_fn = opt.make_step(donate=False)
    else:
        step_fn = opt.make_step(donate=False)

    curve = []
    t0 = time.time()
    for t in range(steps):
        if deterministic:
            batch = full
        else:
            batch = _to_jax(stream.batch(t + 1))
        if name == "gt-srvr" and t % opt.hyper.q == 0:
            state, metrics = anchor_fn(state, full)
        else:
            state, metrics = step_fn(state, batch)
        if (t + 1) % eval_every == 0 or t == 0:
            m = convergence_metric(problem, state.x, state.y, full)
            curve.append({"step": t + 1, "loss": float(metrics.loss),
                          "M_t": float(m["M_t"]),
                          "consensus_x": float(m["consensus_x"]),
                          "stiefel_residual": float(m["stiefel_residual"])})
    wall = time.time() - t0
    return {"method": name, "deterministic": deterministic, "curve": curve,
            "final_loss": curve[-1]["loss"], "final_M_t": curve[-1]["M_t"],
            "us_per_step": wall / steps * 1e6}


def run(steps_det: int = 120, steps_stoch: int = 150) -> dict:
    det = [run_method("drgda", steps_det, True),
           run_method("gt-gda", steps_det, True)]
    stoch = [run_method("drsgda", steps_stoch, False),
             run_method("gnsd-a", steps_stoch, False),
             run_method("dm-hsgd", steps_stoch, False),
             run_method("gt-srvr", steps_stoch, False)]
    return {"figure1_deterministic": det, "figure2_stochastic": stoch}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
