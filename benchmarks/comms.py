"""Communication-layer study: bits on the wire vs consensus vs optimization.

Part A — pure gossip (ring, n=20): per-compressor sweep of bits-per-parameter
against consensus error after a fixed round budget, full precision vs
error-feedback int8 / top-k / low-rank vs *naive* (no-memory) int8.  The
headline number backing the subsystem: EF-int8 reaches consensus error within
2x of full-precision gossip while encoding each parameter in 8 bits instead
of 32 (4x fewer; per-node scale metadata is reported separately as
``total_bits_per_param``).

Part B — channel faults: empirical per-round mixing rate of the effective
``W_t`` sequence under link drops / stragglers / schedules, next to the
static-W ``lambda_2``.

Part C — end-to-end: DRGDA on the paper's fair-classification workload with
the comms layer in the loop (full vs EF-int8 vs EF-int8 + 5% link drops),
comparing final ``M_t``.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.comms import CommEngine, CommSpec, ChannelModel, tree_bits, \
    tree_param_count
from repro.core.gossip import GossipSpec

N_NODES = 20
ROUNDS = 64

#: compressor sweep: (label, CommSpec | None for exact gossip, payload bits/entry)
VARIANTS = [
    ("full", None, 32.0),
    ("int8_ef", CommSpec(compressor="int8", gamma=0.95), 8.0),
    ("int8_naive", CommSpec(compressor="int8", gamma=0.95,
                            error_feedback=False), 8.0),
    ("topk_ef", CommSpec(compressor="topk", topk_frac=0.1, gamma=0.4),
     0.1 * 64.0),
    ("lowrank_ef", CommSpec(compressor="lowrank", rank=2, gamma=0.2), None),
]


def _gossip_tree(seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return {"w_stiefel": jax.random.normal(key, (N_NODES, 64, 8)),
            "w_eucl": jax.random.normal(jax.random.fold_in(key, 1),
                                        (N_NODES, 2048))}


def _consensus_err(tree) -> float:
    return float(sum(jnp.sum((l - jnp.mean(l, 0, keepdims=True)) ** 2)
                     for l in jax.tree.leaves(tree)))


def gossip_sweep(rounds: int = ROUNDS) -> list[dict]:
    tree0 = _gossip_tree()
    params = tree_param_count(tree0)
    err0 = _consensus_err(tree0)
    rows = []
    for label, comm, payload_bits in VARIANTS:
        spec = GossipSpec(topology="ring", n_nodes=N_NODES, k_steps=1,
                          comm=comm)
        if comm is None:
            step = jax.jit(lambda x, t: spec.mix(x, steps=1))
            x = tree0
            for t in range(rounds):
                x = step(x, t)
            final, total_bits = _consensus_err(x), 32.0 * params
        else:
            eng = CommEngine(spec)
            step = jax.jit(
                lambda x, cs, t: eng.mix(cs, "x", x, steps=1, rnd=t))
            x, cs = tree0, eng.init_state({"x": tree0})
            for t in range(rounds):
                x, cs = step(x, cs, t)
            final, total_bits = _consensus_err(x), tree_bits(eng.compressor,
                                                             tree0)
        rows.append({
            "variant": label, "rounds": rounds,
            "bits_per_param": (payload_bits if payload_bits is not None
                               else total_bits / params),
            "total_bits_per_param": total_bits / params,
            "consensus_err_initial": err0, "consensus_err_final": final,
            "contraction": final / err0,
        })
    full = next(r for r in rows if r["variant"] == "full")
    for r in rows:
        r["err_ratio_vs_full"] = (r["consensus_err_final"]
                                  / max(full["consensus_err_final"], 1e-30))
        r["bits_ratio_vs_full"] = (full["bits_per_param"]
                                   / max(r["bits_per_param"], 1e-30))
    return rows


def ef_vs_naive(rounds: int = 256) -> dict:
    """Long-horizon separation: error feedback drives consensus error to ~0,
    naive quantized gossip plateaus at the compressor's noise floor."""
    tree0 = _gossip_tree(seed=7)
    finals = {}
    for label, ef in (("ef", True), ("naive", False)):
        comm = CommSpec(compressor="int8", gamma=0.95, error_feedback=ef)
        eng = CommEngine(GossipSpec(topology="ring", n_nodes=N_NODES,
                                    k_steps=1, comm=comm))
        step = jax.jit(lambda x, cs, t: eng.mix(cs, "x", x, steps=1, rnd=t))
        x, cs = tree0, eng.init_state({"x": tree0})
        for t in range(rounds):
            x, cs = step(x, cs, t)
        finals[label] = _consensus_err(x)
    return {"rounds": rounds, "ef_final": finals["ef"],
            "naive_final": finals["naive"],
            "separation": finals["naive"] / max(finals["ef"], 1e-30)}


def channel_rates() -> list[dict]:
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, k_steps=1)
    cases = [
        ("clean", CommSpec()),
        ("drop10", CommSpec(drop_rate=0.1)),
        ("drop30", CommSpec(drop_rate=0.3)),
        ("straggler20", CommSpec(straggler_rate=0.2)),
        ("round_robin", CommSpec(schedule="round_robin")),
        ("matching", CommSpec(schedule="matching")),
        ("lossy_matching", CommSpec(drop_rate=0.1, straggler_rate=0.1,
                                    schedule="matching")),
    ]
    rows = []
    for label, comm in cases:
        ch = ChannelModel.for_gossip(spec, comm)
        r = ch.empirical_mixing_rate(rounds=48)
        rows.append({"channel": label, **r,
                     "n_edge_subsets": ch.n_subsets})
    return rows


def fair_runs(steps: int = 40) -> list[dict]:
    from benchmarks import fair_classification as fc
    from repro.core import OPTIMIZERS
    from repro.core.gda import GDAHyper
    from repro.core.metric import convergence_metric

    cases = [
        ("full", None),
        ("int8_ef", CommSpec(compressor="int8", gamma=0.95)),
        ("int8_ef_drop5", CommSpec(compressor="int8", gamma=0.95,
                                   drop_rate=0.05)),
    ]
    rows = []
    for label, comm in cases:
        stream, problem, x0, y0 = fc._setup()
        spec = GossipSpec(topology="ring", n_nodes=fc.N_NODES, k_steps=1,
                          comm=comm)
        opt = OPTIMIZERS["drgda"](problem, spec,
                                  GDAHyper(alpha=0.5, beta=0.05, eta=0.2))
        full_batch = fc._to_jax(stream.full(n_batches=4))
        state = opt.init(x0, y0, full_batch)
        step_fn = opt.make_step(donate=False)
        t0 = time.time()
        for _ in range(steps):
            state, metrics = step_fn(state, full_batch)
        wall = time.time() - t0
        m = convergence_metric(problem, state.x, state.y, full_batch)
        bits = (tree_bits(opt.engine.compressor, state.x)
                if opt.engine is not None else 32.0 * tree_param_count(state.x))
        rows.append({"variant": label, "steps": steps,
                     "final_M_t": float(m["M_t"]),
                     "final_consensus_x": float(m["consensus_x"]),
                     "final_loss": float(metrics.loss),
                     "x_bits_per_param_per_mix":
                         bits / tree_param_count(state.x),
                     "us_per_step": wall / steps * 1e6})
    return rows


def run(steps: int = 40) -> dict:
    t0 = time.time()
    sweep = gossip_sweep()
    separation = ef_vs_naive()
    channels = channel_rates()
    fair = fair_runs(steps=steps)
    int8 = next(r for r in sweep if r["variant"] == "int8_ef")
    return {
        "gossip_sweep": sweep,
        "ef_vs_naive": separation,
        "channel_rates": channels,
        "fair_classification": fair,
        # acceptance: EF-int8 within 2x of full-precision consensus error at
        # >=4x fewer bits per parameter, and error feedback beats naive
        "int8_ef_err_ratio": int8["err_ratio_vs_full"],
        "int8_ef_bits_ratio": int8["bits_ratio_vs_full"],
        "acceptance_2x_err_4x_bits": bool(
            int8["err_ratio_vs_full"] <= 2.0
            and int8["bits_ratio_vs_full"] >= 4.0),
        "ef_beats_naive": bool(separation["separation"] > 10.0),
        "us_total": (time.time() - t0) * 1e6,
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
