"""Assemble EXPERIMENTS.md §Dry-run, §Roofline and §Telemetry from
experiments/dryrun/*.json and experiments/bench/obs.json.  §Perf iterations
and §Paper-repro are appended by hand as the hillclimb proceeds
(hypothesis → change → before → after).

Usage:  PYTHONPATH=src python -m benchmarks.build_report [--write]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline_report import load_records

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_bytes(b) -> str:
    if b is None:
        return "-"
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}TiB"


def dryrun_section(recs: list[dict]) -> str:
    out = ["## §Dry-run\n"]
    out.append(
        "Every (architecture × input shape × mesh) lowered **and compiled**\n"
        "with ShapeDtypeStruct inputs (no allocation). `train_4k` lowers the\n"
        "full DRSGDA step (gossip + tracking + retraction) on the per-arch\n"
        "(node, fsdp, model) refinement of the 16×16(×2) grid; decode shapes\n"
        "lower `serve_step` (1 token vs a seq_len cache) on the canonical\n"
        "mesh; `long_500k` uses the documented SWA variant on\n"
        "full-attention archs. Per-device payloads below; compile times are\n"
        "CPU-host (512 placeholder devices).\n")
    out.append("| arch | shape | mesh | chips | variant | args/dev | temps/dev "
               "| HLO GFLOPs/dev | collective MiB/dev | compile_s |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda r: (r["arch"],
                                         SHAPE_ORDER.index(r["shape"]),
                                         r["mesh"])):
        ma = r.get("memory_analysis", {})
        rl = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r.get('variant') or '-'} "
            f"| {_fmt_bytes(ma.get('argument_size_in_bytes'))} "
            f"| {_fmt_bytes(ma.get('temp_size_in_bytes'))} "
            f"| {rl['flops_per_dev'] / 1e9:.1f} "
            f"| {rl['collective_bytes_per_dev'] / 2**20:.1f} "
            f"| {r.get('compile_s', '-')} |")
    out.append("")
    return "\n".join(out)


def roofline_section(recs: list[dict]) -> str:
    out = ["## §Roofline\n"]
    out.append(
        "v5e terms per device: compute = FLOPs/197e12, memory = "
        "bytes/819e9, collective = collective_bytes/50e9 (GB/s/link ICI).\n"
        "`useful` = MODEL_FLOPS (6·N_active·D train, 2·N_active·D decode) / "
        "global HLO FLOPs — <1 means remat/dispatch overhead, >1 means\n"
        "sub-quadratic attention beats the dense-FLOPs model.  Single-pod\n"
        "table (the multi-pod pass proves the pod axis shards; see §Dry-run).\n")
    out.append("| arch | shape | compute_s | memory_s | collective_s | "
               "dominant | useful | bottleneck note |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted([r for r in recs if r["mesh"] == "single"],
                    key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]))):
        rl = r["roofline"]
        uf = r.get("useful_fraction")
        note = _note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} "
            f"| {rl['memory_s']:.3e} | {rl['collective_s']:.3e} "
            f"| **{rl['dominant']}** | "
            f"{uf:.3f} | {note} |" if uf is not None else
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} "
            f"| {rl['memory_s']:.3e} | {rl['collective_s']:.3e} "
            f"| **{rl['dominant']}** | - | {note} |")
    out.append("")
    return "\n".join(out)


def _note(r: dict) -> str:
    rl = r["roofline"]
    dom = rl["dominant"]
    cb = rl.get("collective_breakdown", {})
    if dom == "collective" and cb:
        top = max(cb, key=cb.get)
        return (f"{top} dominates ({cb[top] / 2**20:.0f} MiB/dev) — reduce "
                "via sharding/gossip schedule")
    if dom == "memory":
        return "HBM-bound: fuse/bf16/cache layout are the levers"
    return "compute-bound: near roofline, MXU utilization is the lever"


def telemetry_section(obs: dict | None, serve: dict | None = None) -> str:
    """§Telemetry from experiments/bench/obs.json (step-time breakdown +
    per-round wire bytes) and experiments/bench/serve.json (decode service
    throughput/latency + replica drift).  Empty when neither bench ran."""
    if not obs and not serve:
        return ""
    if not obs:
        return "## §Telemetry\n\n" + _serve_rows(serve)
    out = ["## §Telemetry\n"]
    out.append(
        f"`benchmarks/run.py obs` — DRGDA, {obs['n_nodes']} nodes, ring, "
        f"flush every {obs['flush_every']} steps.  Counters ride the jitted\n"
        f"step as one packed f32[6] state leaf; ordinary steps compile to an\n"
        f"effect-free executable, the io_callback flush lands on one call\n"
        f"per window (`repro.obs`).\n")
    out.append(
        f"* overhead: **{obs['overhead_pct']:.2f}%** "
        f"({obs['us_per_step_off']:.0f} -> {obs['us_per_step_on']:.0f} "
        f"us/step, min over {obs.get('repeats', '?')} interleaved blocks)")
    out.append(f"* obs-on trajectory bit-identical: "
               f"**{obs['bit_identical']}**")
    out.append(
        f"* counter-vs-oracle bytes/hop relative error: "
        f"**{obs['bytes_per_hop_rel_err']:.1e}** "
        f"({obs['bytes_per_hop']:.0f} B/hop measured)\n")

    pb = obs.get("phase_breakdown", {})
    if pb:
        out.append("Step-time breakdown (separately-jitted phases):\n")
        out.append("| phase | us/call | fraction |")
        out.append("|---|---|---|")
        for name, us in pb["us_per_call"].items():
            out.append(f"| {name} | {us:.0f} | {pb['fraction'][name]:.2f} |")
        out.append("")

    slots = obs.get("per_slot_est_hop_bytes", {})
    hops = obs.get("per_slot_hops", {})
    if slots and hops:
        out.append("Wire bytes per gossip round (slot × hops, "
                   "`est_hop_bytes` oracle):\n")
        out.append("| slot | hops/round | bytes/hop | bytes/round |")
        out.append("|---|---|---|---|")
        for slot, b in slots.items():
            h = hops.get(slot, 1)
            out.append(f"| {slot} | {h} | {_fmt_bytes(b)} "
                       f"| {_fmt_bytes(b * h)} |")
        out.append("")

    ke = obs.get("kernel_estimates", {})
    if ke:
        out.append("Analytical kernel estimates for one traced step "
                   "(multiply by executed steps for run totals):\n")
        out.append("| kernel | calls/trace | GFLOP | mem | FLOP/byte |")
        out.append("|---|---|---|---|---|")
        for name, rec in ke.items():
            out.append(f"| {name} | {rec['calls']} | {rec['ops'] / 1e9:.3f} "
                       f"| {_fmt_bytes(rec['mem'])} "
                       f"| {rec['intensity']:.1f} |")
        out.append("")
    if serve:
        out.append(_serve_rows(serve))
    return "\n".join(out)


def _serve_rows(serve: dict) -> str:
    """Decode-service rows: throughput/latency vs slots, continuous-vs-
    static race, paged-kernel accuracy, 2-replica drift trace."""
    out = [
        f"Decode service (`benchmarks/run.py serve` — {serve['arch']}, "
        f"page_size {serve['page_size']}, continuous batching over the "
        f"paged KV cache):\n",
        "| n_slots | tok/s | p50 ms | p99 ms | ttft p50 ms | waves |",
        "|---|---|---|---|---|---|",
    ]
    for n, r in sorted(serve["per_batch"].items(), key=lambda kv: int(kv[0])):
        out.append(f"| {n} | {r['tok_per_s']:.0f} | {r['p50_ms']:.0f} "
                   f"| {r['p99_ms']:.0f} | {r['ttft_p50_ms']:.0f} "
                   f"| {r['steps']} |")
    cont, stat = serve["continuous"], serve["static"]
    out.append(
        f"\n* continuous vs static refill (same workload, same slots): "
        f"**{serve['speedup_vs_static']:.2f}x** tok/s "
        f"({cont['tok_per_s']:.0f} vs {stat['tok_per_s']:.0f}), p99 "
        f"{cont['p99_ms']:.0f} vs {stat['p99_ms']:.0f} ms")
    out.append(
        f"* paged-decode kernel vs oracle (ragged slots, fp32): max err "
        f"**{serve['kernel_max_err']:.1e}**")
    rep = serve["replica"]
    trace = " -> ".join(f"{d:.4f}" for d in rep["drift_trace"])
    wire = rep["wire"]
    frac = wire["wire_bytes"] / max(wire["raw_bytes"], 1)
    out.append(
        f"* {rep['n_replicas']}-replica EF-int8 gossip sync: drift "
        f"{rep['drift_injected']:.4f} -> {trace} "
        f"(bounded, monotone; int8 wire = {frac:.0%} of raw)\n")
    return "\n".join(out)


def autotune_section(tune: dict | None) -> str:
    """§Autotune from experiments/bench/tune.json (and the live cache):
    tuned vs default launch configs per kernel/shape.  Empty string when
    the tune bench hasn't run."""
    if not tune or not tune.get("rows"):
        return ""
    out = ["## §Autotune\n"]
    out.append(
        "`benchmarks/run.py tune` — measure-or-load over each kernel's\n"
        "launch-config space (`repro.kernels.tune`), accuracy-gated where\n"
        "the config changes math (`ns_iters`, rtol 1e-5), 5% hysteresis vs\n"
        "the default, cached under `experiments/tune/<device>.json` keyed\n"
        "on kernel|shape|dtype.  `REPRO_TUNE=off|load|search`; delete the\n"
        "cache dir to retune.\n")
    out.append("| kernel | shape | tuned config | default config | "
               "tuned us | default us | speedup |")
    out.append("|---|---|---|---|---|---|---|")
    for r in tune["rows"]:
        shape = "x".join(str(s) for s in r["shape"])
        if r.get("extra"):
            shape += " " + ",".join(f"{k}={v}"
                                    for k, v in sorted(r["extra"].items()))
        cfg = ", ".join(f"{k}={v}" for k, v in sorted(r["config"].items()))
        dflt = ", ".join(f"{k}={v}"
                         for k, v in sorted(r["default_config"].items()))
        mark = "**" if r["config"] != r["default_config"] else ""
        out.append(f"| {r['kernel']} | {shape} | {mark}{cfg}{mark} | {dflt} "
                   f"| {r['best_us']:.1f} | {r['default_us']:.1f} "
                   f"| {r['speedup_pct']:+.1f}% |")
    if tune.get("searches") is not None:
        out.append(f"\ncache searches recorded: {tune['searches']} "
                   f"(unchanged on re-run — second invocation is pure load)")
    out.append("")
    return "\n".join(out)


def churn_section(elastic: dict | None) -> str:
    """§Elastic gossip from experiments/bench/elastic.json: M_t / consensus
    per churn schedule on both paper problems, plus the live-node trace
    from the membership telemetry riding each eval row.  Empty string when
    the elastic bench hasn't run."""
    if not elastic or "fair_classification" not in elastic:
        return ""
    out = ["## §Elastic gossip\n"]
    out.append(
        "`benchmarks/run.py elastic` — DRGDA on an 8-node ring under\n"
        "membership churn and stale-hop tolerance (`repro.comms.elastic`).\n"
        "Departed nodes stop sending and receiving (their W_t rows fold to\n"
        "the identity, keeping every realized round doubly stochastic over\n"
        "the live subgraph); rejoining nodes re-enter from their neighbours'\n"
        "projected consensus mean.  All churn draws are seeded.\n")
    out.append("| problem | schedule | final M_t | final consensus | "
               "live trace | finite |")
    out.append("|---|---|---|---|---|---|")
    for r in elastic["fair_classification"] + elastic["robust_pca"]:
        live = "/".join(str(row.get("live", "-")) for row in r["curve"])
        out.append(
            f"| {r['problem']} | {r['schedule']} | {r['final_M_t']:.4f} "
            f"| {r['final_consensus']:.2e} | {live} | {r['finite']} |")
    out.append(
        f"\n* scripted leave-then-rejoin vs static ring (fair "
        f"classification): M_t ratio "
        f"**{elastic['leave_rejoin_Mt_ratio']:.2f}** — within 2x: "
        f"**{elastic['leave_rejoin_within_2x']}**")
    out.append(f"* every schedule finite on both problems: "
               f"**{elastic['all_finite']}**\n")
    return "\n".join(out)


def analysis_section(analysis: dict | None) -> str:
    """§Static analysis from experiments/bench/analysis.json (written by
    ``python -m repro.analysis``): pass/finding counts per analysis pass.
    Empty string when the CLI hasn't run."""
    if not analysis or "passes" not in analysis:
        return ""
    out = ["## §Static analysis\n"]
    out.append(
        f"`python -m repro.analysis` on `{analysis.get('hw', '?')}` — jaxpr\n"
        "lint (weak-type-leak / effect-in-quiet-path / donation-miss /\n"
        "comm-schedule), Pallas VMEM + tiling + oracle-coverage checks, and\n"
        "the doubly-stochastic / manifold-feasibility contract validators\n"
        "over the registered entry points.  Nonzero findings fail CI.\n")
    out.append("| pass | findings |")
    out.append("|---|---|")
    for name, findings in analysis["passes"].items():
        cell = "ok" if not findings else "; ".join(
            f"[{f['rule']}] {f['where']}" for f in findings[:4])
        out.append(f"| {name} | {cell} |")
    out.append(f"\ntotal findings: {analysis.get('n_findings', '?')} "
               f"({analysis.get('elapsed_s', '?')}s)")
    out.append("")
    return "\n".join(out)


def _load_bench(name: str) -> dict | None:
    path = os.path.join(ROOT, "experiments", "bench", f"{name}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def load_obs() -> dict | None:
    return _load_bench("obs")


def build(recs, obs=None, tune=None, serve=None, analysis=None,
          elastic=None) -> str:
    text = dryrun_section(recs) + "\n" + roofline_section(recs)
    for section in (telemetry_section(obs, serve), autotune_section(tune),
                    churn_section(elastic), analysis_section(analysis)):
        if section:
            text += "\n" + section
    return text


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true",
                    help="rewrite the §Dry-run/§Roofline block in EXPERIMENTS.md")
    args = ap.parse_args()
    recs = load_records()
    text = build(recs, obs=load_obs(), tune=_load_bench("tune"),
                 serve=_load_bench("serve"), analysis=_load_bench("analysis"),
                 elastic=_load_bench("elastic"))
    if args.write:
        path = os.path.join(ROOT, "EXPERIMENTS.md")
        marker_a = "<!-- AUTOGEN:DRYRUN-ROOFLINE:BEGIN -->"
        marker_b = "<!-- AUTOGEN:DRYRUN-ROOFLINE:END -->"
        if os.path.exists(path):
            cur = open(path).read()
        else:
            cur = f"# EXPERIMENTS\n\n{marker_a}\n{marker_b}\n"
        if marker_a in cur:
            pre = cur.split(marker_a)[0]
            post = cur.split(marker_b)[1]
            cur = pre + marker_a + "\n" + text + "\n" + marker_b + post
        else:
            cur += f"\n{marker_a}\n{text}\n{marker_b}\n"
        with open(path, "w") as f:
            f.write(cur)
        print(f"wrote {path} ({len(recs)} records)")
    else:
        print(text)
