"""Retraction micro-bench: fused vs unfused vs eigh, per (d, r) sweep.

Times one node-stacked Stiefel retraction step — the DRGDA x-update hot
spot — per retraction implementation:

  * ``polar_fused``   — kernels.ops.fused_retract (the Pallas kernel on
    TPU; its jnp oracle here, which still fuses the tangent projection into
    the same dispatch and shares the FLOP structure);
  * ``polar_ns``      — unfused tangent_project + retract_polar(method="ns")
    (two Grams + NS + apply as separate XLA ops);
  * ``polar_eigh``    — the eigh oracle path (exact, not MXU-friendly);
  * ``qr``            — jnp.linalg.qr retraction;
  * ``cayley``        — matmul-only CG Cayley (geometry.stiefel).

Writes experiments/bench/geometry.json via ``benchmarks/run.py geometry``.
"""
from __future__ import annotations

import time

import jax

from repro.core import manifolds as M
from repro.kernels import ops

SWEEP = [(256, 32), (512, 64), (1024, 128)]
N_NODES = 8


def _impls():
    return {
        "polar_fused": lambda x, g: ops.fused_retract(x, g),
        "polar_ns": lambda x, g: M.retract_polar(
            x, M.tangent_project(x, g), method="ns"),
        "polar_eigh": lambda x, g: M.retract_polar(
            x, M.tangent_project(x, g), method="eigh"),
        "qr": lambda x, g: M.retract_qr(x, M.tangent_project(x, g)),
        "cayley": lambda x, g: M.retract_cayley(x, M.tangent_project(x, g)),
    }


def _time(fn, x, g, iters: int = 20) -> float:
    jfn = jax.jit(fn)
    jfn(x, g).block_until_ready()          # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jfn(x, g)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> dict:
    rows = []
    t_start = time.time()
    for d, r in SWEEP:
        key = jax.random.PRNGKey(d)
        x = M.random_stiefel(key, d, r, batch=(N_NODES,))
        g = 0.1 * jax.random.normal(jax.random.fold_in(key, 1),
                                    (N_NODES, d, r))
        base = None
        for name, fn in _impls().items():
            us = _time(fn, x, g)
            out = jax.jit(fn)(x, g)
            feas = float(M.stiefel_error(out).max())
            rows.append({"d": d, "r": r, "n_nodes": N_NODES, "impl": name,
                         "us_per_call": us, "feasibility": feas})
            if name == "polar_eigh":
                base = us
        for row in rows[-len(_impls()):]:
            row["speedup_vs_eigh"] = base / max(row["us_per_call"], 1e-9)
    return {"rows": rows, "backend": jax.default_backend(),
            "us_total": (time.time() - t_start) * 1e6}
