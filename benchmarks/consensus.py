"""Consensus/communication study backing the paper's W^k machinery:

* empirical contraction rate of k-step gossip vs the lambda_2^k theory,
  per topology (ring / torus / full / star);
* the Theorem-1 k prescription vs n;
* Stiefel consensus: IAM error under repeated project-mix-retract rounds
  (the manifold analogue the x-update performs).
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import gossip as G, manifolds as M


def contraction(topology: str, n: int, k: int, seed: int = 0) -> dict:
    spec = G.GossipSpec(topology=topology, n_nodes=n, k_steps=k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 32))
    xbar = jnp.mean(x, 0, keepdims=True)
    before = float(jnp.linalg.norm(x - xbar))
    after = float(jnp.linalg.norm(spec.mix(x) - xbar))
    lam = spec.lam2
    rate = after / before
    return {"topology": topology, "n": n, "k": k,
            "empirical_rate": rate, "lambda2_pow_k": lam ** k,
            # lambda_2^k upper-bounds the disagreement contraction
            "bound_satisfied": rate <= lam ** k + 1e-6}


def stiefel_consensus_rounds(n: int = 12, rounds: int = 120, seed: int = 0) -> list:
    base = M.random_stiefel(jax.random.PRNGKey(seed), 24, 4)
    noise = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1), (n, 24, 4))
    xs = jax.vmap(lambda e: M.retract_polar(base, M.tangent_project(base, e)))(noise)
    spec = G.GossipSpec(topology="ring", n_nodes=n, k_steps=1)
    errs = []
    for _ in range(rounds):
        mixed = spec.mix(xs)
        cons = jax.vmap(M.tangent_project)(xs, mixed)    # alpha = 1
        xs = jax.vmap(lambda x, u: M.retract_polar(x, 0.5 * u))(xs, cons)
        errs.append(float(M.consensus_error(xs)))
    return errs


def run() -> dict:
    t0 = time.time()
    rows = []
    for topo in ("ring", "torus", "full", "star"):
        for n in (8, 20):
            for k in (1, 2, 4, 8):
                rows.append(contraction(topo, n, k))
    theory = [{"n": n, "k_theorem1": G.required_gossip_steps(G.ring_matrix(n))}
              for n in (4, 8, 16, 20, 32, 64)]
    st_err = stiefel_consensus_rounds()
    return {
        "contraction": rows,
        "theorem1_k": theory,
        "stiefel_consensus_errors": st_err[::10],
        "stiefel_consensus_converged": st_err[-1] < 1e-2 * st_err[0],
        "us_total": (time.time() - t0) * 1e6,
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
