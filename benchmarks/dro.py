"""Paper supplementary experiment — distributionally robust optimization
with orthonormal weights (Eq. 21): DRSGDA vs GNSD-A / DM-HSGD on the
heterogeneous classification stream, ring of n=20."""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import OPTIMIZERS
from repro.core.baselines import HSGDHyper
from repro.core.gda import GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.metric import convergence_metric
from repro.data.synthetic import ClassificationStream
from repro.objectives import fair

N_NODES = 20


def run_method(name: str, steps: int, seed: int = 0) -> dict:
    stream = ClassificationStream(n_nodes=N_NODES, batch_per_node=32,
                                  seed=seed, hetero=0.9)
    params = fair.init_cnn(jax.random.PRNGKey(seed), image_hw=stream.image_hw)
    problem = fair.make_dro_problem(params)
    x0 = broadcast_to_nodes(params, N_NODES)
    y0 = jnp.full((N_NODES, 3), 1.0 / 3.0)
    spec = GossipSpec(topology="ring", n_nodes=N_NODES, k_steps=1)
    cls = OPTIMIZERS[name]
    opt = cls(problem, spec, HSGDHyper(beta=0.05, eta=0.2)) \
        if name == "dm-hsgd" else \
        cls(problem, spec, GDAHyper(alpha=0.5, beta=0.05, eta=0.2))

    to_jax = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
    state = opt.init(x0, y0, to_jax(stream.batch(0)))
    step_fn = opt.make_step(donate=False)
    curve = []
    t0 = time.time()
    eval_batch = to_jax(stream.full(2))
    for t in range(steps):
        state, metrics = step_fn(state, to_jax(stream.batch(t + 1)))
        if (t + 1) % 10 == 0 or t == 0:
            m = convergence_metric(problem, state.x, state.y, eval_batch)
            curve.append({"step": t + 1, "loss": float(metrics.loss),
                          "M_t": float(m["M_t"]),
                          "worst_group_weight": float(jnp.max(state.y))})
    return {"method": name, "curve": curve,
            "final_loss": curve[-1]["loss"], "final_M_t": curve[-1]["M_t"],
            "us_per_step": (time.time() - t0) / steps * 1e6}


def run(steps: int = 120) -> dict:
    # equal sample budget: DM-HSGD does two grad passes per step
    return {"dro": [run_method("drsgda", steps),
                    run_method("gnsd-a", steps),
                    run_method("dm-hsgd", steps // 2)]}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
