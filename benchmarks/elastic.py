"""Elastic-gossip benchmark: convergence under membership churn.

Sweeps churn schedule x stale-hop tolerance tau on the two paper problems
that exercise both manifolds:

* fair classification (Stiefel CNN head, Eq. 19/20) with DRGDA — including
  the acceptance scenario: a scripted leave-then-rejoin run must stay
  finite and land within 2x of the static-ring M_t;
* robust PCA (Grassmann subspace, Eq. 21-style adversary) with DRGDA.

Each run records the M_t / consensus curve plus membership telemetry
(live-node count per eval), so the report can plot convergence against the
realized churn.  All churn draws are seeded — rerunning the benchmark
reproduces the same leave/join sequence bit-for-bit.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.elastic import ChurnSchedule, ElasticSpec
from repro.core import DRGDA
from repro.core.gda import GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.metric import convergence_metric
from repro.data.synthetic import ClassificationStream
from repro.geometry import GRASSMANN
from repro.objectives import fair
from repro.objectives import robust_pca as rp

N = 8  # ring size for every elastic run (churn on n=20 tells the same story)

SCHEDULES: dict[str, ElasticSpec | None] = {
    # baseline: no elastic engine at all — the exact main-path program
    "static": None,
    # the acceptance scenario: one node leaves, later rejoins
    "leave_rejoin": ElasticSpec(churn=ChurnSchedule(
        kind="scripted", events=((10, "leave", 3), (30, "join", 3)))),
    # sustained seeded churn at two rates, with and without tolerance
    "random_5pct": ElasticSpec(churn=ChurnSchedule(
        kind="random", leave_rate=0.05, join_rate=0.5)),
    "random_20pct": ElasticSpec(churn=ChurnSchedule(
        kind="random", leave_rate=0.20, join_rate=0.5)),
    "straggle_tau0": ElasticSpec(tau=0, straggler_rate=0.3),
    "straggle_tau2": ElasticSpec(tau=2, straggler_rate=0.3),
}


def _membership_row(state) -> dict:
    mem = getattr(state.comm, "elastic", None)
    if mem is None:
        return {"live": N}
    act = np.asarray(mem.active)
    return {"live": int(act.sum()), "active": act.astype(int).tolist()}


def _drive(opt, problem, state, batch_fn, eval_batch, steps, eval_every):
    step_fn = opt.make_step(donate=False)
    curve = []
    t0 = time.time()
    for t in range(steps):
        state, metrics = step_fn(state, batch_fn(t))
        if (t + 1) % eval_every == 0 or t == 0:
            m = convergence_metric(problem, state.x, state.y, eval_batch)
            curve.append({"step": t + 1, "loss": float(metrics.loss),
                          "M_t": float(m["M_t"]),
                          "consensus_x": float(m["consensus_x"]),
                          **_membership_row(state)})
    wall = time.time() - t0
    return {"curve": curve, "final_M_t": curve[-1]["M_t"],
            "final_consensus": curve[-1]["consensus_x"],
            "finite": all(np.isfinite(r["M_t"]) for r in curve),
            "us_per_step": wall / steps * 1e6}


def run_fair(name: str, elastic: ElasticSpec | None, steps: int = 60,
             seed: int = 0) -> dict:
    stream = ClassificationStream(n_nodes=N, batch_per_node=32, seed=seed)
    params = fair.init_cnn(jax.random.PRNGKey(seed),
                           image_hw=stream.image_hw)
    problem = fair.make_fair_problem(params, rho=1.0)
    x0 = broadcast_to_nodes(params, N)
    y0 = jnp.full((N, 3), 1.0 / 3.0)
    gossip = GossipSpec(topology="ring", n_nodes=N, k_steps=1,
                        elastic=elastic)
    opt = DRGDA(problem, gossip, GDAHyper(alpha=0.5, beta=0.05, eta=0.2))
    full = {k: jnp.asarray(v) for k, v in stream.full(n_batches=4).items()}
    state = opt.init(x0, y0, full)
    res = _drive(opt, problem, state, lambda t: full, full, steps,
                 eval_every=10)
    return {"problem": "fair_classification", "schedule": name, **res}


def run_pca(name: str, elastic: ElasticSpec | None, steps: int = 200,
            seed: int = 1) -> dict:
    problem = rp.make_robust_pca_problem(rho=0.5)
    batches, _ = rp.make_batches(jax.random.PRNGKey(seed), n_nodes=N,
                                 m=24, d=20, r=3, outlier_frac=0.1,
                                 outlier_scale=1.5)
    x0 = broadcast_to_nodes(
        {"w": GRASSMANN.rand(jax.random.PRNGKey(0), 20, 3)}, N)
    y0 = rp.init_y(N, 24)
    gossip = GossipSpec(topology="ring", n_nodes=N, k_steps=1,
                        elastic=elastic)
    opt = DRGDA(problem, gossip, GDAHyper(alpha=0.5, beta=0.1, eta=0.3))
    state = opt.init(x0, y0, batches)
    res = _drive(opt, problem, state, lambda t: batches, batches, steps,
                 eval_every=25)
    return {"problem": "robust_pca", "schedule": name, **res}


def run(steps_fair: int = 60, steps_pca: int = 200) -> dict:
    t0 = time.time()
    fair_rows = [run_fair(n, e, steps=steps_fair)
                 for n, e in SCHEDULES.items()]
    pca_rows = [run_pca(n, e, steps=steps_pca)
                for n, e in SCHEDULES.items()]

    by = {r["schedule"]: r for r in fair_rows}
    static, churn = by["static"], by["leave_rejoin"]
    ratio = churn["final_M_t"] / max(static["final_M_t"], 1e-12)
    return {
        "fair_classification": fair_rows,
        "robust_pca": pca_rows,
        "leave_rejoin_Mt_ratio": ratio,
        # acceptance: finite and within 2x of the static ring
        "leave_rejoin_within_2x": bool(churn["finite"] and ratio <= 2.0),
        "all_finite": all(r["finite"] for r in fair_rows + pca_rows),
        "us_total": (time.time() - t0) * 1e6,
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
