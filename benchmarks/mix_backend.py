"""Mix-backend benchmark: stacked vs shard_map (fused / unfused) gossip.

For a sweep of per-node model sizes, times jitted ``W^k`` mixes under the
stacked backend and BOTH shard_map schedules on an 8-virtual-device node
mesh — ``shard_map`` is the fused halo-panel megakernel path (one Pallas
launch for all k hops), ``shard_map_unfused`` the hop-by-hop schedule it
replaced — and reports hops/sec plus each backend's *estimated bytes moved
per hop*.  A second sweep holds the size at ``tiny_64k`` (where launch
latency dominates and the fusion matters most) and scales the hop count
k in {1, 2, 3, 5} for all three schedules.  The unfused column is ring-only:
dense topologies take the all-gather path, identical under both flags.

Because the device count must be forced before jax initializes, ``run()``
re-executes this file in a worker subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and collects JSON
from stdout; ``benchmarks/run.py mix`` saves it to
``experiments/bench/mix_backend.json``.

On this CPU container the timing is a *schedule* benchmark (one host backs
all 8 devices, so wall-clock gains are modest); the bytes-per-hop column is
the hardware-independent signal the perf trajectory tracks.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DEVICES = 8
N_NODES = 16          # two node rows per device: only edge rows hit the wire
STEPS = 3
REPEATS = 6           # timed mixes per block
BLOCKS = 5            # best-of-BLOCKS guards against host load spikes

# per-node leaf layouts: (name, [(leaf shape sans node axis), ...])
SIZES = [
    ("tiny_64k", [(128, 128), (16384,)]),
    ("small_512k", [(256, 512), (8, 128, 128), (131072,)]),
    ("medium_2m", [(512, 1024), (16, 256, 256), (524288,)]),
]


def _worker() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.comms.backend import ShardMapBackend, StackedBackend
    from repro.core.gossip import GossipSpec

    mesh = Mesh(np.asarray(jax.devices())[:N_DEVICES].reshape(N_DEVICES),
                ("node",))
    backends = {"stacked": StackedBackend(),
                "shard_map": ShardMapBackend(mesh, axis="node", fuse="on"),
                "shard_map_unfused": ShardMapBackend(mesh, axis="node",
                                                     fuse="off")}

    def _make_tree(leaf_shapes):
        key = jax.random.PRNGKey(0)
        return {f"l{i}": jax.random.normal(jax.random.fold_in(key, i),
                                           (N_NODES, *shp), jnp.float32)
                for i, shp in enumerate(leaf_shapes)}

    def _time_row(size, tree, topology, bname, be, k):
        spec = GossipSpec(topology=topology, n_nodes=N_NODES, k_steps=k)
        fn = jax.jit(lambda t, _be=be, _s=spec, _k=k: _be.mix(_s, t, _k))
        out = jax.block_until_ready(fn(tree))       # compile + warm
        dt = float("inf")
        for _ in range(BLOCKS):
            t0 = time.time()
            for _ in range(REPEATS):
                out = jax.block_until_ready(fn(out))
            dt = min(dt, (time.time() - t0) / REPEATS)
        params = sum(int(l.size) for l in jax.tree.leaves(tree)) // N_NODES
        return {
            "size": size, "params_per_node": params,
            "topology": topology, "backend": bname, "k": k,
            "us_per_mix": dt * 1e6,
            "hops_per_sec": k / dt,
            "est_bytes_per_hop": be.est_hop_bytes(spec, tree),
        }

    rows = []
    t_all = time.time()
    for name, leaf_shapes in SIZES:
        tree = _make_tree(leaf_shapes)
        for topology in ("ring", "full"):
            for bname, be in backends.items():
                if bname == "shard_map_unfused" and topology != "ring":
                    continue    # dense path is flag-independent
                rows.append(_time_row(name, tree, topology, bname, be,
                                      STEPS))
    # hop-count sweep at the latency-dominated size: hops/sec vs k
    sweep_tree = _make_tree(dict(SIZES)["tiny_64k"])
    k_sweep = []
    for k in (1, 2, 3, 5):
        for bname, be in backends.items():
            k_sweep.append(_time_row("tiny_64k", sweep_tree, "ring",
                                     bname, be, k))
    return {"n_devices": N_DEVICES, "n_nodes": N_NODES,
            "rows": rows, "k_sweep": k_sweep,
            "us_total": (time.time() - t_all) * 1e6}


def run() -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{N_DEVICES}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(_REPO_ROOT, "src"), _REPO_ROOT]))
    out = subprocess.run([sys.executable, os.path.abspath(__file__),
                          "--worker"], env=env, capture_output=True,
                         text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"mix_backend worker failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


if __name__ == "__main__":
    if "--worker" in sys.argv:
        for _p in (os.path.join(_REPO_ROOT, "src"), _REPO_ROOT):
            if _p not in sys.path:
                sys.path.insert(0, _p)
        print(json.dumps(_worker()))
    else:
        print(json.dumps(run(), indent=1))
