"""Mix-backend benchmark: stacked vs shard_map gossip hops.

For a sweep of per-node model sizes, times jitted ``W^k`` mixes under both
backends on an 8-virtual-device node mesh and reports hops/sec plus each
backend's *estimated bytes moved per hop* (the stacked roll ships every node
row both ways — and dense topologies all-gather — where the shard_map ring
ships only the two edge rows per device).

Because the device count must be forced before jax initializes, ``run()``
re-executes this file in a worker subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and collects JSON
from stdout; ``benchmarks/run.py mix`` saves it to
``experiments/bench/mix_backend.json``.

On this CPU container the timing is a *schedule* benchmark (one host backs
all 8 devices, so wall-clock gains are modest); the bytes-per-hop column is
the hardware-independent signal the perf trajectory tracks.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_DEVICES = 8
N_NODES = 16          # two node rows per device: only edge rows hit the wire
STEPS = 3
REPEATS = 30

# per-node leaf layouts: (name, [(leaf shape sans node axis), ...])
SIZES = [
    ("tiny_64k", [(128, 128), (16384,)]),
    ("small_512k", [(256, 512), (8, 128, 128), (131072,)]),
    ("medium_2m", [(512, 1024), (16, 256, 256), (524288,)]),
]


def _worker() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.comms.backend import ShardMapBackend, StackedBackend
    from repro.core.gossip import GossipSpec

    mesh = Mesh(np.asarray(jax.devices())[:N_DEVICES].reshape(N_DEVICES),
                ("node",))
    backends = {"stacked": StackedBackend(),
                "shard_map": ShardMapBackend(mesh, axis="node")}
    rows = []
    t_all = time.time()
    for name, leaf_shapes in SIZES:
        key = jax.random.PRNGKey(0)
        tree = {f"l{i}": jax.random.normal(jax.random.fold_in(key, i),
                                           (N_NODES, *shp), jnp.float32)
                for i, shp in enumerate(leaf_shapes)}
        params = sum(int(l.size) for l in jax.tree.leaves(tree)) // N_NODES
        for topology in ("ring", "full"):
            spec = GossipSpec(topology=topology, n_nodes=N_NODES,
                              k_steps=STEPS)
            for bname, be in backends.items():
                fn = jax.jit(lambda t, _be=be, _s=spec: _be.mix(_s, t, STEPS))
                out = jax.block_until_ready(fn(tree))   # compile + warm
                t0 = time.time()
                for _ in range(REPEATS):
                    out = jax.block_until_ready(fn(out))
                dt = (time.time() - t0) / REPEATS
                rows.append({
                    "size": name, "params_per_node": params,
                    "topology": topology, "backend": bname, "k": STEPS,
                    "us_per_mix": dt * 1e6,
                    "hops_per_sec": STEPS / dt,
                    "est_bytes_per_hop": be.est_hop_bytes(spec, tree),
                })
    return {"n_devices": N_DEVICES, "n_nodes": N_NODES,
            "rows": rows, "us_total": (time.time() - t_all) * 1e6}


def run() -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{N_DEVICES}",
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(_REPO_ROOT, "src"), _REPO_ROOT]))
    out = subprocess.run([sys.executable, os.path.abspath(__file__),
                          "--worker"], env=env, capture_output=True,
                         text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"mix_backend worker failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


if __name__ == "__main__":
    if "--worker" in sys.argv:
        for _p in (os.path.join(_REPO_ROOT, "src"), _REPO_ROOT):
            if _p not in sys.path:
                sys.path.insert(0, _p)
        print(json.dumps(_worker()))
    else:
        print(json.dumps(run(), indent=1))
