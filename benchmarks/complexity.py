"""Theorem 1/2 sanity check: the convergence-metric decay rate.

Theorem 1 gives (1/T) sum_t M_t <= O(1/T) for DRGDA (so an eps^2-stationary
point needs T ~ eps^-2).  We run the toy Stiefel minimax problem, fit the
log-log slope of the running average of M_t vs T, and check it is ~ -1
(within tolerance).  For DRSGDA with fixed batch the bound saturates at the
variance floor; we report the floor too.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import manifolds as M
from repro.core.gda import DRGDA, GDAHyper, broadcast_to_nodes
from repro.core.gossip import GossipSpec
from repro.core.metric import convergence_metric
from repro.core.minimax import MinimaxProblem, project_simplex

D, R, G, N = 10, 2, 3, 8
RHO = 1.0


def _problem(seed=0):
    a = np.stack([np.random.RandomState(seed + i).randn(D, D)
                  for i in range(G)])
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)

    def loss_fn(x, y, batch):
        ag = a + batch
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return jnp.dot(y, lg) - RHO * jnp.sum((y - 1.0 / G) ** 2)

    def y_star(x, batches):
        ag = a + jnp.mean(batches, axis=0)
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return project_simplex(1.0 / G + lg / (2 * RHO))

    return MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          manifold_map={"w": "stiefel"}, y_star=y_star)


def run(steps: int = 400) -> dict:
    t0 = time.time()
    prob = _problem()
    spec = GossipSpec(topology="ring", n_nodes=N)
    opt = DRGDA(prob, spec, GDAHyper(alpha=0.5, beta=0.03, eta=0.3))
    x0 = broadcast_to_nodes(
        {"w": M.random_stiefel(jax.random.PRNGKey(5), D, R)}, N)
    y0 = jnp.full((N, G), 1.0 / G)
    batches = 0.1 * jax.random.normal(jax.random.PRNGKey(6), (N, G, D, D))
    state = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)

    running, ms = 0.0, []
    checkpoints = sorted({int(steps * f) for f in
                          (0.05, 0.1, 0.2, 0.4, 0.7, 1.0)})
    for t in range(steps):
        state, _ = step(state, batches)
        if (t + 1) in checkpoints:
            m = convergence_metric(prob, state.x, state.y, batches)
            ms.append({"T": t + 1, "M_t": float(m["M_t"])})

    ts = np.array([r["T"] for r in ms], float)
    vals = np.array([max(r["M_t"], 1e-12) for r in ms], float)
    slope = float(np.polyfit(np.log(ts), np.log(vals), 1)[0])
    return {
        "curve": ms,
        "loglog_slope": slope,
        # O(1/T) average-metric bound => instantaneous M_t decays at least
        # ~T^-1 on this strongly structured toy; slope should be <= ~-0.8
        "consistent_with_theorem1": slope < -0.8,
        "us_total": (time.time() - t0) * 1e6,
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
