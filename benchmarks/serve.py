"""Serving benchmark: continuous vs static batching + replica sync.

A burst of mixed-length requests (ragged prompts, ragged token budgets) is
decoded through ``repro.serve`` on the smollm-135m reduced config:

* **batch-size sweep** — tokens/sec and p50/p99 request latency vs
  ``n_slots`` under continuous batching;
* **continuous vs static** — same workload, same slots; static admission
  (drain the whole wave before refilling) is the ablation, continuous
  refills slots the moment one frees — the throughput gap is the paper
  point of the scheduler;
* **paged kernel accuracy** — the block-table gather kernel
  (``pallas_interpret``) vs its NumPy-style oracle on ragged slots;
* **replica sync** — a 2-replica EF-int8 gossip run: perturb, sync, report
  the cross-replica drift trace + wire bytes.

The run emits ``serve`` + ``replica`` telemetry events and validates the
event log against ``obs/event_schema.json`` (the CI smoke gate).  Payload
lands in experiments/bench/serve.json via ``benchmarks/run.py serve``.

Run:  PYTHONPATH=src python benchmarks/serve.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH = "smollm-135m"
PAGE_SIZE = 8
N_PAGES = 257                # 256 usable pages + the dump page
MAX_PAGES_PER_SLOT = 8       # 64-token max context per slot
SEED = 0


def _requests(n: int, seed: int):
    """Mixed workload: ragged prompts (4..28) and strongly ragged budgets
    (4..32), all arriving at t=0 — the shape static batching handles
    worst: every wave is held hostage by its longest request."""
    import numpy as np
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, 200, rng.integers(4, 29)).tolist(),
                    max_new_tokens=int(rng.integers(4, 33)))
            for _ in range(n)]


def _drive(engine, spec, n_slots, refill, requests, telemetry=None):
    from repro.serve import ContinuousBatchingScheduler, serve_requests
    sched = ContinuousBatchingScheduler(n_slots, spec, refill=refill)
    t0 = time.perf_counter()
    fin = serve_requests(engine, sched, requests)
    wall = time.perf_counter() - t0
    import numpy as np
    lats = np.asarray([r.latency for r in fin])
    ttfts = np.asarray([r.ttft for r in fin])
    n_tok = sum(len(r.tokens) for r in fin)
    res = {
        "n_requests": len(fin), "n_tokens": n_tok,
        "wall_s": round(wall, 3),
        "tok_per_s": round(n_tok / wall, 1),
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 1),
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 1),
        "ttft_p50_ms": round(float(np.percentile(ttfts, 50)) * 1e3, 1),
        "steps": engine.steps_run,
    }
    if telemetry is not None:
        telemetry.event("serve", {
            "kind": "summary", "refill": refill, "n_slots": n_slots, **res})
    return res


def _kernel_check():
    """Paged-decode Pallas kernel (interpret) vs oracle on ragged slots."""
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    s, hkv, g, hd, ps, m = 5, 2, 3, 32, 8, 6
    n_pages = 24
    q = jnp.asarray(rng.normal(size=(s, hkv * g, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(n_pages, ps, hkv, hd)), jnp.float32)
    seq = [1, 7, 13, 0, 40]
    bt = np.full((s, m), -1, np.int32)
    nxt = 1
    for i, sl in enumerate(seq):
        for j in range(-(-sl // ps)):
            bt[i, j] = nxt
            nxt += 1
    bt, seq = jnp.asarray(bt), jnp.asarray(seq, jnp.int32)
    want = ops.paged_decode_attention(q, kp, vp, bt, seq, impl="ref")
    got = ops.paged_decode_attention(q, kp, vp, bt, seq,
                                     impl="pallas_interpret",
                                     pages_per_block=2)
    return float(jnp.abs(got - want).max())


def run(smoke: bool = False) -> dict:
    import jax
    from repro import configs
    from repro.models import transformer as T
    from repro.obs.telemetry import Telemetry
    from repro.obs import events
    from repro.serve import PagedKVSpec, ReplicaGroup, ServeEngine

    cfg = configs.get_config(ARCH, smoke=True)
    params = T.init_params(jax.random.PRNGKey(SEED), cfg)
    spec = PagedKVSpec(page_size=PAGE_SIZE, n_pages=N_PAGES,
                       max_pages_per_slot=MAX_PAGES_PER_SLOT)

    out_dir = os.path.join(_REPO_ROOT, "experiments", "bench", "serve_run")
    tel = Telemetry(run="serve_bench", out_dir=out_dir)
    if os.path.exists(tel.events_path):     # fresh log per run
        os.remove(tel.events_path)

    n_req = 8 if smoke else 32
    slot_sweep = (2,) if smoke else (1, 2, 4, 8)

    def engine(n_slots):
        from repro.serve import Request
        e = ServeEngine(cfg, params, kv_spec=spec, n_slots=n_slots,
                        temperature=0.0, seed=SEED, telemetry=None)
        # warm the prefill/step jit caches so timings measure decode, not
        # compiles: one prompt per page-count bucket the workload can hit
        # (prompt lens 4..28 at page_size 8 -> 1..4 pages)
        warm = [Request(prompt=[1] * n, max_new_tokens=2)
                for n in range(PAGE_SIZE // 2,
                               MAX_PAGES_PER_SLOT * PAGE_SIZE - 20,
                               PAGE_SIZE)]
        _drive(e, spec, n_slots, "continuous", warm)
        e.steps_run = e.tokens_generated = 0
        return e

    per_batch = {}
    for n_slots in slot_sweep:
        per_batch[n_slots] = _drive(engine(n_slots), spec, n_slots,
                                    "continuous", _requests(n_req, 1), tel)

    n_race = max(slot_sweep)
    cont = _drive(engine(n_race), spec, n_race, "continuous",
                  _requests(n_req, 2), tel)
    stat = _drive(engine(n_race), spec, n_race, "static",
                  _requests(n_req, 2), tel)
    speedup = cont["tok_per_s"] / max(stat["tok_per_s"], 1e-9)

    kernel_max_err = _kernel_check()

    rg = ReplicaGroup(params, 2, seed=SEED, telemetry=tel)
    drift0 = rg.perturb(0.02)
    trace = rg.sync(rounds=2 if smoke else 4)

    tel.export()
    n_events = events.validate_log(tel.events_path)

    payload = {
        "arch": cfg.name, "page_size": PAGE_SIZE, "n_pages": N_PAGES,
        "max_pages_per_slot": MAX_PAGES_PER_SLOT, "smoke": smoke,
        "per_batch": {str(k): v for k, v in per_batch.items()},
        "continuous": cont, "static": stat,
        "speedup_vs_static": round(speedup, 3),
        "kernel_max_err": kernel_max_err,
        "replica": {
            "n_replicas": 2, "drift_injected": drift0,
            "drift_trace": trace, "drift_final": trace[-1],
            "wire": rg.wire_stats(),
        },
        "n_events": n_events,
        "events_path": os.path.relpath(tel.events_path, _REPO_ROOT),
        "us_per_token": round(1e6 * cont["wall_s"] / cont["n_tokens"], 1),
    }
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    res = run(smoke=args.smoke)
    print(json.dumps(res, indent=1))
    assert res["kernel_max_err"] < 2e-5, res["kernel_max_err"]
    assert res["replica"]["drift_final"] < res["replica"]["drift_injected"]
    if not args.smoke:
        assert res["speedup_vs_static"] > 1.0, res["speedup_vs_static"]
    return 0


if __name__ == "__main__":
    import sys
    for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)
    raise SystemExit(main())
