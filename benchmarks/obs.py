"""Telemetry benchmark: overhead, bit-identity and counter agreement.

One DRGDA workload (N nodes, ring, the toy Stiefel minimax problem from the
optimizer tests) is run three ways:

* **off** — ``telemetry=None``: the pre-obs program;
* **on**  — counters threaded + io_callback flush every FLUSH_EVERY steps;
* **phases** — the same step split into separately-jitted compute / retract
  / mix / metric pieces, timed per phase (in-jit phase timing is impossible;
  this is the step-time breakdown §Telemetry reports).

Checks performed (all land in experiments/bench/obs.json):

* wall-clock overhead of obs on vs off (<5% acceptance at the default
  cadence);
* the two final states are bit-identical (counters never touch the math);
* counter-derived bytes/hop equals the backend's ``est_hop_bytes`` oracle —
  the same number ``benchmarks/mix_backend.py`` records — within 1%;
* kernel Estimates snapshot for the traced step (per-traced-call; multiply
  by executed steps for run totals).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

N_NODES = 8
# sized so one step is O(2ms) on this container — small enough to keep the
# bench fast, big enough that the obs cost (a fixed ~100us/step dispatch +
# flush tax) is measured against a realistic step, not a toy one
D, R, G = 192, 16, 3
RHO = 1.0
BLOCK = FLUSH_EVERY = 50   # timed blocks of one flush window each
REPEATS = 14


def _problem():
    import jax.numpy as jnp
    import numpy as np
    from repro.core.minimax import MinimaxProblem, project_simplex

    a = np.stack([np.random.RandomState(i).randn(D, D) for i in range(G)])
    a = jnp.asarray((a + np.swapaxes(a, 1, 2)) / 2, jnp.float32)

    def loss_fn(x, y, batch):
        ag = a + batch
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return jnp.dot(y, lg) - RHO * jnp.sum((y - 1.0 / G) ** 2)

    def y_star(x, batches):
        ag = a + jnp.mean(batches, axis=0)
        lg = -jnp.einsum("dr,gde,er->g", x["w"], ag, x["w"])
        return project_simplex(1.0 / G + lg / (2 * RHO))

    return MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                          manifold_map={"w": "stiefel"}, y_star=y_star)


def _setup():
    import jax
    import jax.numpy as jnp
    from repro.core import manifolds as M
    from repro.core.gda import broadcast_to_nodes

    prob = _problem()
    batches = 0.1 * jax.random.normal(jax.random.PRNGKey(6),
                                      (N_NODES, G, D, D))
    x0 = broadcast_to_nodes(
        {"w": M.random_stiefel(jax.random.PRNGKey(5), D, R)}, N_NODES)
    y0 = jnp.full((N_NODES, G), 1.0 / G)
    return prob, x0, y0, batches


def _prep(opt, x0, y0, batches):
    """Warm both executables (flush path on call 1, quiet path on call 2)
    and return (step, state0)."""
    import jax
    state0 = opt.init(x0, y0, batches)
    step = opt.make_step(donate=False)
    s, m = step(state0, batches)
    jax.block_until_ready(m.loss)
    s, m = step(s, batches)
    jax.block_until_ready(m.loss)
    return step, state0


def _block(step, state0, batches, steps=BLOCK):
    """One timed block of ``steps`` calls from state0; since BLOCK ==
    FLUSH_EVERY, every obs-on block pays exactly one flush call.  Returns
    (final_state, seconds/step)."""
    import jax
    state = state0
    t0 = time.time()
    for _ in range(steps):
        state, m = step(state, batches)
    jax.block_until_ready(m.loss)
    return state, (time.time() - t0) / steps


def _phase_breakdown(opt, prob, x0, y0, batches):
    """compute / retract / mix / metric wall-clock per call, each phase
    jitted separately (approximates the in-step split)."""
    import jax
    import jax.numpy as jnp
    from repro.core.gda import _vmapped_loss_and_rgrads

    state = opt.init(x0, y0, batches)
    h = opt.hyper

    def compute(x, y, b):
        return _vmapped_loss_and_rgrads(prob, x, y, b)

    def retract(x, u):
        return jax.tree.map(
            lambda m, xl, ul: m.retract(
                xl, -h.beta * ul, m.resolve_retraction(h.retraction)),
            prob.manifold_map, x, u)

    def mix(x):
        return opt.backend.mix(opt.gossip, x, opt.k)

    def metric(x, y, b):
        from repro.core.metric import convergence_metric
        return convergence_metric(prob, x, y, b)["M_t"]

    phases = {
        "compute": (jax.jit(compute), (state.x, state.y, batches)),
        "retract": (jax.jit(retract), (state.x, state.u)),
        "mix": (jax.jit(mix), (state.x,)),
        "metric": (jax.jit(metric), (state.x, state.y, batches)),
    }
    out = {}
    for name, (fn, args) in phases.items():
        jax.block_until_ready(fn(*args))     # compile
        t0 = time.time()
        for _ in range(20):
            r = fn(*args)
        jax.block_until_ready(r)
        out[name] = (time.time() - t0) / 20 * 1e6
    total = sum(out.values())
    return {"us_per_call": out,
            "fraction": {k: v / total for k, v in out.items()}}


def run() -> dict:
    import jax
    import numpy as np
    from repro.core.gda import DRGDA, GDAHyper
    from repro.core.gossip import GossipSpec
    from repro.obs import Telemetry, estimates as obs_est, unpack
    from repro.obs import events as obs_events
    from repro.obs import telemetry as obs_telemetry
    from repro.obs import trace as obs_trace

    prob, x0, y0, batches = _setup()
    spec = GossipSpec(topology="ring", n_nodes=N_NODES)
    out_dir = tempfile.mkdtemp(prefix="obs_bench_")
    tel = Telemetry(run="bench", out_dir=out_dir, flush_every=FLUSH_EVERY)

    # warmed steppers, then tightly interleaved off/on timed blocks;
    # min-over-blocks is the noise-robust estimator on this shared container
    # (load spikes only ever add time).  Each block restarts from state0, so
    # both arms execute the identical 50-step trajectory every time.
    opt_off = DRGDA(prob, spec, GDAHyper())
    opt_on = DRGDA(prob, spec, GDAHyper(), telemetry=tel)
    step_off, s_off0 = _prep(opt_off, x0, y0, batches)
    step_on, s_on0 = _prep(opt_on, x0, y0, batches)
    t_off, t_on = [], []
    for _ in range(REPEATS):
        state_off, dt = _block(step_off, s_off0, batches)
        t_off.append(dt)
        state_on, dt = _block(step_on, s_on0, batches)
        t_on.append(dt)
    dt_off, dt_on = float(np.min(t_off)), float(np.min(t_on))
    overhead = (dt_on - dt_off) / dt_off * 100.0

    bit_identical = all(
        bool((a == b).all()) for a, b in
        zip(jax.tree.leaves(state_on.x), jax.tree.leaves(state_off.x)))

    # counter-derived bytes/hop vs the mix-backend oracle.  DRGDA mixes four
    # slots per step (x, y, u with k hops; v with 1): expected bytes/hop is
    # the hop-weighted mean of the per-slot est_hop_bytes.
    obs = unpack(state_on.obs)
    k = opt_on.k
    per_slot = {s: opt_on.backend.est_hop_bytes(spec, t) for s, t in
                (("x", x0), ("y", y0), ("u", x0), ("v", y0))}
    hops = {"x": k, "y": k, "u": k, "v": 1}
    expect = sum(per_slot[s] * hops[s] for s in per_slot) / sum(hops.values())
    got = float(obs.wire_bytes) / float(obs.hops)
    rel_err = abs(got - expect) / expect

    # kernel Estimates for one traced step (per-traced-call semantics)
    obs_est.GLOBAL.reset()
    with obs_est.collect() as kc:
        opt2 = DRGDA(prob, spec, GDAHyper(retraction="polar_fused"))
        st2 = opt2.init(x0, y0, batches)
        jax.block_until_ready(opt2.make_step(donate=False)(st2, batches))
    kernel_snapshot = kc.snapshot()

    # event-log artifacts: schema-validate + trace round-trip
    n_events = obs_events.validate_log(tel.events_path)
    paths = tel.export()
    payload = json.load(open(paths["trace"]))
    rt = obs_trace.Trace.from_chrome_trace(payload)
    counters = obs_telemetry.read_counter_series(tel.events_path)

    return {
        "n_nodes": N_NODES, "block": BLOCK, "repeats": REPEATS,
        "flush_every": FLUSH_EVERY,
        "us_per_step_off": dt_off * 1e6,
        "us_per_step_on": dt_on * 1e6,
        "overhead_pct": overhead,
        "bit_identical": bit_identical,
        "counters": {kk: float(v) for kk, v in obs.as_dict().items()},
        "bytes_per_hop": got,
        "bytes_per_hop_expected": expect,
        "bytes_per_hop_rel_err": rel_err,
        "per_slot_est_hop_bytes": per_slot,
        "per_slot_hops": hops,
        "n_flushes": len(counters),
        "n_events": n_events,
        "trace_roundtrip_events": len(rt.events),
        "phase_breakdown": _phase_breakdown(opt_on, prob, x0, y0, batches),
        "kernel_estimates": kernel_snapshot,
        "artifacts": paths,
    }


if __name__ == "__main__":
    for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
        if _p not in os.sys.path:
            os.sys.path.insert(0, _p)
    print(json.dumps(run(), indent=1))
