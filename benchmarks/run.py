"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows, writes the full JSON payloads
to experiments/bench/, and appends one compact summary record per entry
(name, key metrics, git rev, timestamp) to the top-level BENCH_summary.json
so regressions are visible across revisions without diffing payloads.

  fair_det    — Fig. 1: DRGDA vs GT-GDA (deterministic fair classification)
  fair_stoch  — Fig. 2: DRSGDA vs GNSD-A / DM-HSGD / GT-SRVR
  dro         — supplementary: DRO with orthonormal weights (Eq. 21)
  consensus   — W^k contraction vs lambda_2^k theory; Stiefel consensus
  comms       — bits-per-parameter vs consensus error vs final M_t sweep
                (EF-int8 / top-k / low-rank / naive; channel fault rates)
  mix         — stacked vs shard_map (fused/unfused) backend: hops/sec +
                est bytes moved per gossip hop across model sizes and hop
                counts (8 virtual devices)
  tune        — autotuned vs default Pallas launch configs on the demo
                shapes (writes experiments/bench/tune.json; asserts the
                second lookup is a pure cache load)
  geometry    — retraction micro-bench: fused kernel vs unfused NS vs eigh
                (+ qr / cayley), node-stacked (d, r) sweep
  complexity  — Theorem-1 decay-rate sanity (log-log slope of M_t)
  roofline    — dry-run roofline table summary (reads experiments/dryrun)
  obs         — telemetry overhead + counter-vs-estimate agreement
  serve       — decode service: tokens/sec + p99 latency vs batch size,
                continuous vs static batching, paged-kernel accuracy,
                2-replica gossip drift (writes experiments/bench/serve.json)
  elastic     — elastic-gossip churn sweep: M_t / consensus vs churn rate
                and stale-hop tolerance tau on fair classification and
                robust PCA; checks the scripted leave-then-rejoin run stays
                within 2x of the static ring
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# make `python benchmarks/run.py ...` work from anywhere: the repo root (for
# the `benchmarks` package) and src/ (for `repro`) must be importable
for _p in (_REPO_ROOT, os.path.join(_REPO_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

BENCH_DIR = os.path.join(_REPO_ROOT, "experiments", "bench")
SUMMARY_PATH = os.path.join(_REPO_ROOT, "BENCH_summary.json")


def _save(name: str, payload: dict) -> None:
    os.makedirs(BENCH_DIR, exist_ok=True)
    with open(os.path.join(BENCH_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=10).stdout.strip() or "?"
    except Exception:
        return "?"


def append_summary(name: str, us_per_call: float, derived: str,
                   rev: str | None = None) -> dict:
    """Append one compact record to the top-level BENCH_summary.json.

    The file holds a flat list, newest last; ``derived`` is the same
    key=value string the CSV row prints, split into a dict for grepping.
    """
    metrics: dict = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            try:
                metrics[k] = float(v)
            except ValueError:
                metrics[k] = v
    rec = {"name": name, "us_per_call": round(us_per_call, 1),
           "metrics": metrics, "git_rev": rev or _git_rev(),
           "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S")}
    rows = []
    if os.path.exists(SUMMARY_PATH):
        try:
            with open(SUMMARY_PATH) as f:
                rows = json.load(f)
        except Exception:
            rows = []
    rows.append(rec)
    with open(SUMMARY_PATH, "w") as f:
        json.dump(rows, f, indent=1)
    return rec


def bench_fair_det():
    from benchmarks import fair_classification as fc
    res = {"figure1_deterministic": [fc.run_method("drgda", 100, True),
                                     fc.run_method("gt-gda", 100, True)]}
    _save("fair_det", res)
    runs = res["figure1_deterministic"]
    us = sum(r["us_per_step"] for r in runs) / len(runs)
    drgda = next(r for r in runs if r["method"] == "drgda")
    gtgda = next(r for r in runs if r["method"] == "gt-gda")
    derived = (f"drgda_final_Mt={drgda['final_M_t']:.4f};"
               f"gtgda_final_Mt={gtgda['final_M_t']:.4f};"
               f"drgda_wins={drgda['final_M_t'] <= gtgda['final_M_t']}")
    return us, derived


def bench_fair_stoch():
    from benchmarks import fair_classification as fc
    # equal SAMPLE budget (the paper's complexity metric): DM-HSGD and
    # GT-SRVR evaluate two gradients per step -> half the steps
    runs = [fc.run_method("drsgda", 120, False),
            fc.run_method("gnsd-a", 120, False),
            fc.run_method("dm-hsgd", 60, False),
            fc.run_method("gt-srvr", 60, False)]
    _save("fair_stoch", {"figure2_stochastic": runs})
    us = sum(r["us_per_step"] for r in runs) / len(runs)
    finals = {r["method"]: r["final_M_t"] for r in runs}
    best = min(finals, key=finals.get)
    derived = ";".join(f"{k}_Mt={v:.4f}" for k, v in finals.items()) + \
        f";best={best}"
    return us, derived


def bench_dro():
    from benchmarks import dro
    res = dro.run(steps=100)  # dro.run halves two-pass methods internally
    _save("dro", res)
    runs = res["dro"]
    us = sum(r["us_per_step"] for r in runs) / len(runs)
    finals = {r["method"]: r["final_M_t"] for r in runs}
    best = min(finals, key=finals.get)
    return us, ";".join(f"{k}_Mt={v:.4f}" for k, v in finals.items()) + \
        f";best={best}"


def bench_consensus():
    from benchmarks import consensus
    res = consensus.run()
    _save("consensus", res)
    ok = sum(r["bound_satisfied"] for r in res["contraction"])
    return res["us_total"] / max(len(res["contraction"]), 1), \
        (f"stiefel_consensus_converged={res['stiefel_consensus_converged']};"
         f"lambda2k_bound_holds={ok}/{len(res['contraction'])}")


def bench_comms():
    from benchmarks import comms
    res = comms.run()
    _save("comms", res)
    n_rows = len(res["gossip_sweep"]) + len(res["channel_rates"]) + \
        len(res["fair_classification"])
    fair = {r["variant"]: r["final_M_t"] for r in res["fair_classification"]}
    derived = (f"int8_ef_err_ratio={res['int8_ef_err_ratio']:.2f};"
               f"int8_ef_bits_ratio={res['int8_ef_bits_ratio']:.1f};"
               f"acceptance_2x_err_4x_bits={res['acceptance_2x_err_4x_bits']};"
               f"ef_beats_naive={res['ef_beats_naive']};"
               + ";".join(f"{k}_Mt={v:.4f}" for k, v in fair.items()))
    return res["us_total"] / max(n_rows, 1), derived


def bench_mix():
    from benchmarks import mix_backend
    res = mix_backend.run()
    _save("mix_backend", res)
    rows = res["rows"]
    ring = [r for r in rows if r["topology"] == "ring"]
    by = {r["backend"]: r for r in ring if r["size"] == "medium_2m"}
    sm, st = by["shard_map"], by["stacked"]
    tiny = {r["backend"]: r for r in ring if r["size"] == "tiny_64k"}
    fused, unfused = tiny["shard_map"], tiny["shard_map_unfused"]
    derived = (f"ring64k_fused_hps={fused['hops_per_sec']:.1f};"
               f"ring64k_unfused_hps={unfused['hops_per_sec']:.1f};"
               f"ring2m_shardmap_hps={sm['hops_per_sec']:.1f};"
               f"ring2m_stacked_hps={st['hops_per_sec']:.1f};"
               f"ring2m_bytes_ratio="
               f"{st['est_bytes_per_hop'] / max(sm['est_bytes_per_hop'], 1):.1f}")
    return res["us_total"] / max(len(rows), 1), derived


def bench_tune():
    """Autotuned vs default launch configs on the demo shapes — searches on
    a cache-miss, then proves the second lookup is a pure load."""
    from repro.kernels import tune as ktune
    os.environ["REPRO_TUNE"] = "search"
    t0 = time.time()
    rows = []
    for name, shape, dtype, extra in ktune.DEMO_SHAPES:
        entry = ktune.autotune(name, tuple(shape), dtype, extra=extra)
        rows.append({
            "kernel": name, "shape": list(shape), "dtype": dtype,
            "extra": extra, "config": entry["config"],
            "default_config": entry["default_config"],
            "best_us": entry["best_us"], "default_us": entry["default_us"],
            "speedup_pct": entry["speedup_pct"], "impl": entry["impl"],
        })
    searches = None
    try:
        with open(ktune.cache_path()) as f:
            searches = json.load(f).get("searches")
    except OSError:
        pass
    # round trip: every key must now serve from cache without re-searching
    for name, shape, dtype, extra in ktune.DEMO_SHAPES:
        assert ktune.lookup(name, tuple(shape), dtype, extra) is not None
    res = {"rows": rows, "cache_path": ktune.cache_path(),
           "searches": searches,
           "us_total": (time.time() - t0) * 1e6}
    _save("tune", res)
    tuned = [r for r in rows if r["config"] != r["default_config"]]
    derived = (f"n_kernels={len(rows)};n_nondefault={len(tuned)};"
               + ";".join(f"{r['kernel']}_speedup_pct={r['speedup_pct']:.1f}"
                          for r in rows))
    return res["us_total"] / max(len(rows), 1), derived


def bench_geometry():
    from benchmarks import geometry
    res = geometry.run()
    _save("geometry", res)
    rows = res["rows"]
    big = [r for r in rows if (r["d"], r["r"]) == (1024, 128)]
    by = {r["impl"]: r for r in big}
    fused, ns, eigh = by["polar_fused"], by["polar_ns"], by["polar_eigh"]
    worst_feas = max(r["feasibility"] for r in rows)
    derived = (f"fused1024_us={fused['us_per_call']:.0f};"
               f"ns1024_us={ns['us_per_call']:.0f};"
               f"eigh1024_us={eigh['us_per_call']:.0f};"
               f"fused_speedup_vs_eigh={fused['speedup_vs_eigh']:.2f};"
               f"max_feasibility_residual={worst_feas:.1e}")
    return res["us_total"] / max(len(rows), 1), derived


def bench_complexity():
    from benchmarks import complexity
    res = complexity.run(steps=300)
    _save("complexity", res)
    return res["us_total"] / 300, \
        (f"loglog_slope={res['loglog_slope']:.2f};"
         f"consistent_with_theorem1={res['consistent_with_theorem1']}")


def bench_roofline():
    from benchmarks import roofline_report
    t0 = time.time()
    res = roofline_report.run()
    _save("roofline", res)
    us = (time.time() - t0) * 1e6
    return us, (f"records={res['n_records']};"
                + ";".join(f"{k}={v}" for k, v in
                           sorted(res["dominant_histogram"].items())))


def bench_obs():
    from benchmarks import obs
    res = obs.run()
    _save("obs", res)
    derived = (f"overhead_pct={res['overhead_pct']:.2f};"
               f"bit_identical={res['bit_identical']};"
               f"bytes_per_hop_rel_err={res['bytes_per_hop_rel_err']:.2e};"
               f"n_flushes={res['n_flushes']};"
               f"n_events={res['n_events']}")
    return res["us_per_step_on"], derived


def bench_elastic():
    from benchmarks import elastic
    res = elastic.run()
    _save("elastic", res)
    rows = res["fair_classification"] + res["robust_pca"]
    fair = {r["schedule"]: r["final_M_t"] for r in res["fair_classification"]}
    derived = (f"leave_rejoin_ratio={res['leave_rejoin_Mt_ratio']:.2f};"
               f"within_2x={res['leave_rejoin_within_2x']};"
               f"all_finite={res['all_finite']};"
               + ";".join(f"{k}_Mt={v:.4f}" for k, v in fair.items()))
    return res["us_total"] / max(len(rows), 1), derived


def bench_serve():
    from benchmarks import serve
    res = serve.run()
    _save("serve", res)
    derived = (f"tok_per_s={res['continuous']['tok_per_s']:.1f};"
               f"p99_ms={res['continuous']['p99_ms']:.1f};"
               f"speedup_vs_static={res['speedup_vs_static']:.2f};"
               f"kernel_max_err={res['kernel_max_err']:.2e};"
               f"drift_final={res['replica']['drift_final']:.2e}")
    return res["us_per_token"], derived


ALL = {
    "fair_det": bench_fair_det,
    "fair_stoch": bench_fair_stoch,
    "dro": bench_dro,
    "consensus": bench_consensus,
    "comms": bench_comms,
    "mix": bench_mix,
    "tune": bench_tune,
    "geometry": bench_geometry,
    "complexity": bench_complexity,
    "roofline": bench_roofline,
    "obs": bench_obs,
    "serve": bench_serve,
    "elastic": bench_elastic,
}


def main() -> None:
    names = sys.argv[1:] or list(ALL)
    rev = _git_rev()
    print("name,us_per_call,derived")
    for name in names:
        try:
            us, derived = ALL[name]()
            print(f"{name},{us:.1f},{derived}", flush=True)
            append_summary(name, us, derived, rev=rev)
        except Exception as e:  # keep the harness going
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)


if __name__ == "__main__":
    main()
