"""Robust PCA on the Grassmann manifold with DRGDA — end to end.

min_{x in Gr(20,3)} max_{y in simplex_24}
    sum_j y_j ||z_j - x x^T z_j||^2 / ||z_j||^2  -  rho ||y - 1/24||^2

over an 8-node ring: the adversary up-weights the worst-reconstructed
samples (the planted outliers), so the learned subspace must hedge against
them instead of optimizing the average.  Only span(x) matters — the
Grassmann geometry (horizontal-space projection, no symmetrization)
quotients out basis rotations that the Stiefel geometry would waste
consensus steps aligning.

Two checks at the end:
  * DRGDA converges in the paper's metric (M_t, Eq. 16) and recovers the
    planted subspace to a small principal-angle distance;
  * the minimax subspace beats plain pooled PCA on the WORST-CASE
    objective Phi(x) = max_y f(x, y) — the robustness the adversary buys.

Run:  PYTHONPATH=src python examples/robust_pca.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DRGDA, GDAHyper, GossipSpec
from repro.core.gda import broadcast_to_nodes
from repro.core.metric import convergence_metric
from repro.geometry import GRASSMANN
from repro.objectives import robust_pca as rp

D, R, M, N, RHO = 20, 3, 24, 8, 0.5

problem = rp.make_robust_pca_problem(rho=RHO)
batches, true_basis = rp.make_batches(
    jax.random.PRNGKey(1), n_nodes=N, m=M, d=D, r=R,
    outlier_frac=0.1, outlier_scale=1.5)

x0 = broadcast_to_nodes({"w": GRASSMANN.rand(jax.random.PRNGKey(0), D, R)}, N)
y0 = rp.init_y(N, M)

opt = DRGDA(problem, GossipSpec(topology="ring", n_nodes=N),
            GDAHyper(alpha=0.5, beta=0.1, eta=0.3))
state = opt.init(x0, y0, batches)
step = opt.make_step(donate=False)

for t in range(800):
    state, metrics = step(state, batches)
    if t % 200 == 0:
        m = convergence_metric(problem, state.x, state.y, batches)
        angle = float(GRASSMANN.dist(state.x["w"][0], true_basis))
        print(f"step {t:4d}  loss={metrics.loss:+.4f}  M_t={m['M_t']:.2e}  "
              f"consensus={m['consensus_x']:.2e}  "
              f"feasibility={m['stiefel_residual']:.2e}  "
              f"angle-to-truth={angle:.3f}")


def worst_case(x):
    """Phi(x) = max_y f(x, y) via the closed-form global maximizer."""
    y_star = rp.robust_pca_y_star({"w": x}, batches, rho=RHO)
    res = jnp.mean(jax.vmap(lambda z: rp.residuals(x, z))(batches["z"]), 0)
    return float(jnp.dot(y_star, res) - RHO * jnp.sum((y_star - 1.0 / M) ** 2))


m = convergence_metric(problem, state.x, state.y, batches)
angle = float(GRASSMANN.dist(state.x["w"][0], true_basis))
z = np.asarray(batches["z"].reshape(-1, D))
pca_basis = jnp.asarray(np.linalg.eigh(z.T @ z)[1][:, -R:])
phi_drgda, phi_pca = worst_case(state.x["w"][0]), worst_case(pca_basis)
print(f"final M_t = {float(m['M_t']):.3e}, angle-to-truth = {angle:.3f} rad")
print(f"worst-case objective: DRGDA {phi_drgda:.4f}  vs  pooled PCA "
      f"{phi_pca:.4f}  (lower is more robust)")
assert float(m["M_t"]) < 5e-3
assert float(m["stiefel_residual"]) < 1e-4
assert angle < 0.5
assert phi_drgda <= phi_pca + 1e-4
