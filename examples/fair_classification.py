"""Paper experiment 1 — orthonormal fair classification networks (Eq. 19/20)
on a ring of 20 nodes: DRGDA (deterministic) or DRSGDA (stochastic) vs the
Euclidean baselines the paper compares against.

Run:  PYTHONPATH=src python examples/fair_classification.py --setting stoch
"""
import argparse

from benchmarks import fair_classification as fc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--setting", choices=["det", "stoch"], default="det")
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    if args.setting == "det":
        methods = ["drgda", "gt-gda"]
        runs = [fc.run_method(m, args.steps, True) for m in methods]
    else:
        methods = ["drsgda", "gnsd-a", "dm-hsgd", "gt-srvr"]
        runs = [fc.run_method(m, args.steps, False) for m in methods]

    print(f"{'method':10s} {'final loss':>11s} {'final M_t':>11s} "
          f"{'St resid':>10s}")
    for r in runs:
        last = r["curve"][-1]
        print(f"{r['method']:10s} {last['loss']:11.4f} {last['M_t']:11.4f} "
              f"{last['stiefel_residual']:10.2e}")
    ours = runs[0]
    best_base = min(runs[1:], key=lambda r: r["final_M_t"])
    print(f"\n{ours['method']} final M_t {ours['final_M_t']:.4f} vs best "
          f"baseline {best_base['method']} {best_base['final_M_t']:.4f}")


if __name__ == "__main__":
    main()
