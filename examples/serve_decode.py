"""Serving example — the paged decode service end-to-end.

Submits a burst of mixed-length prompts to ``repro.serve``'s continuous
batching engine (paged KV cache + block-table Pallas decode kernel), prints
per-request latency, then syncs two drifted replicas with EF-int8 gossip
and prints the drift trace.  The contiguous-cache ``generate`` path is kept
for the architecture families the paged path doesn't cover (MLA compressed
cache, Mamba/xLSTM O(1) state).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.launch.serve import generate
from repro.models import transformer as T
from repro.serve import (ContinuousBatchingScheduler, PagedKVSpec,
                         ReplicaGroup, Request, ServeEngine, serve_requests)

# -- 1. continuous-batching decode over the paged KV cache ------------------

cfg = configs.get_config("smollm-135m", smoke=True)
params = T.init_params(jax.random.PRNGKey(0), cfg)
spec = PagedKVSpec(page_size=8, n_pages=65, max_pages_per_slot=6)
engine = ServeEngine(cfg, params, kv_spec=spec, n_slots=4, temperature=0.7)
sched = ContinuousBatchingScheduler(4, spec)

rng = np.random.default_rng(1)
burst = [Request(prompt=rng.integers(0, 200, rng.integers(4, 25)).tolist(),
                 max_new_tokens=int(rng.integers(6, 16)),
                 arrival=0.002 * i)
         for i in range(10)]

t0 = time.time()
finished = serve_requests(engine, sched, burst)
wall = time.time() - t0
n_tok = sum(len(r.tokens) for r in finished)
print(f"served {len(finished)} requests / {n_tok} tokens in {wall:5.1f}s "
      f"({n_tok / wall:6.1f} tok/s, {engine.steps_run} decode waves)")
for r in sorted(finished, key=lambda r: r.rid):
    print(f"  req {r.rid}: prompt={len(r.prompt):2d} new={len(r.tokens):2d} "
          f"ttft={1e3 * r.ttft:7.1f}ms latency={1e3 * r.latency:7.1f}ms "
          f"sample={r.tokens[:5]}")

# -- 2. replica weight-sync: EF-int8 gossip drift trace ---------------------

group = ReplicaGroup(params, n_replicas=2, seed=0)
d0 = group.perturb(0.02)
trace = group.sync(rounds=4)
wire = group.wire_stats()
print(f"replica drift: injected {d0:.4f} -> " +
      " -> ".join(f"{d:.4f}" for d in trace) +
      f"  (int8 wire {wire['wire_bytes'] / wire['raw_bytes']:.0%} of raw)")

# -- 3. contiguous-cache fallback families ----------------------------------

for arch in ("zamba2-2.7b", "deepseek-v2-236b"):
    acfg = configs.get_config(arch, smoke=True)
    aparams = T.init_params(jax.random.PRNGKey(0), acfg)
    shape = (2, 12) if acfg.n_codebooks == 1 else (2, 12, acfg.n_codebooks)
    prompt = jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                acfg.vocab_size)
    t0 = time.time()
    toks = generate(acfg, aparams, prompt, 8, temperature=0.7)
    dt = time.time() - t0
    print(f"{arch:24s} ({acfg.family:6s}) contiguous decode {toks.shape} "
          f"in {dt:5.1f}s  sample={toks[0].ravel()[:6].tolist()}")
