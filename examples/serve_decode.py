"""Serving example — batched autoregressive decode with KV / recurrent-state
caches, across architecture families (dense KV cache, MLA compressed cache,
Mamba/xLSTM O(1) state, multi-codebook audio).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.serve import generate
from repro.models import transformer as T

for arch in ("smollm-135m", "zamba2-2.7b", "xlstm-1.3b", "musicgen-large",
             "deepseek-v2-236b"):
    cfg = configs.get_config(arch, smoke=True)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    shape = (4, 16) if cfg.n_codebooks == 1 else (4, 16, cfg.n_codebooks)
    prompt = jax.random.randint(jax.random.PRNGKey(1), shape, 0,
                                cfg.vocab_size)
    fe = None
    if cfg.frontend is not None:
        fe = 0.1 * jax.random.normal(
            jax.random.PRNGKey(2),
            (4, cfg.frontend.n_tokens, cfg.frontend.embed_dim))
    t0 = time.time()
    toks = generate(cfg, params, prompt, 12, frontend_embeds=fe,
                    temperature=0.7)
    dt = time.time() - t0
    print(f"{arch:24s} ({cfg.family:6s}) generated {toks.shape} in {dt:5.1f}s "
          f"({4 * 12 / dt:6.1f} tok/s)  sample={toks[0].ravel()[:6].tolist()}")
