"""Quickstart: the paper's algorithm (DRGDA) on a 30-line Stiefel minimax.

Robust PCA-flavoured toy:  min_{x in St(12,3)} max_{y in simplex_3}
sum_g y_g (-tr(x^T A_g x)) - ||y - 1/3||^2 over an 8-node ring.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DRGDA, GDAHyper, GossipSpec, MinimaxProblem
from repro.core import manifolds as M
from repro.core.gda import broadcast_to_nodes
from repro.core.metric import convergence_metric
from repro.core.minimax import project_simplex

D, R, G, N = 12, 3, 3, 8
rng = np.random.default_rng(0)
A = rng.normal(size=(G, D, D))
A = jnp.asarray((A + np.swapaxes(A, 1, 2)) / 2, jnp.float32)


def loss_fn(x, y, batch):                     # one node's local objective
    lg = -jnp.einsum("dr,gde,er->g", x["w"], A + batch, x["w"])
    return jnp.dot(y, lg) - jnp.sum((y - 1.0 / G) ** 2)


def y_star(x, batches):                       # closed-form inner maximizer
    lg = -jnp.einsum("dr,gde,er->g", x["w"], A + batches.mean(0), x["w"])
    return project_simplex(1.0 / G + lg / 2.0)


problem = MinimaxProblem(loss_fn=loss_fn, project_y=project_simplex,
                         manifold_map={"w": "stiefel"}, y_star=y_star)
opt = DRGDA(problem, GossipSpec(topology="ring", n_nodes=N),
            GDAHyper(alpha=0.5, beta=0.03, eta=0.1))

x0 = broadcast_to_nodes({"w": M.random_stiefel(jax.random.PRNGKey(0), D, R)}, N)
y0 = jnp.full((N, G), 1.0 / G)
batches = 0.05 * jax.random.normal(jax.random.PRNGKey(1), (N, G, D, D))

state = opt.init(x0, y0, batches)
step = opt.make_step(donate=False)
for t in range(400):
    state, metrics = step(state, batches)
    if t % 100 == 0:
        m = convergence_metric(problem, state.x, state.y, batches)
        print(f"step {t:4d}  loss={metrics.loss:+.4f}  M_t={m['M_t']:.2e}  "
              f"consensus={m['consensus_x']:.2e}  "
              f"St-residual={m['stiefel_residual']:.2e}")

m = convergence_metric(problem, state.x, state.y, batches)
print(f"final M_t = {float(m['M_t']):.3e}  (stationary + consensus + "
      f"inner-opt, Eq. 16)")
assert float(m["M_t"]) < 1e-3
