"""End-to-end driver — decentralized group-DRO LM pretraining with DRSGDA.

Trains the SmolLM-family architecture (reduced variant by default so a few
hundred steps complete on CPU; pass --full on a real slice) over an 8-node
ring with heterogeneous synthetic domain data, Stiefel-constrained attention
projections, gradient tracking and gossip consensus — the paper's Algorithm
2 driving a real transformer.

Run:  PYTHONPATH=src python examples/decentralized_llm_pretrain.py \
          --steps 300 --nodes 8
"""
import argparse

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="full smollm-135m config (use on a real slice)")
    ap.add_argument("--optimizer", default="drsgda")
    ap.add_argument("--checkpoint-dir", default="checkpoints/smollm-dro")
    args = ap.parse_args()

    argv = ["--arch", "smollm-135m", "--steps", str(args.steps),
            "--nodes", str(args.nodes), "--optimizer", args.optimizer,
            "--batch-per-node", "4", "--seq-len", "128",
            "--eval-every", "20",
            "--checkpoint-dir", args.checkpoint_dir,
            "--checkpoint-every", "100"]
    if not args.full:
        argv.append("--smoke")
    raise SystemExit(train_cli.main(argv))


if __name__ == "__main__":
    main()
